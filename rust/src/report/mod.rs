//! Report helpers shared by the figure/table bench harnesses: run
//! tables, headline iso-accuracy/iso-cost deltas, history CSVs.

pub mod benchkit;

use crate::baselines::CompareResult;
use crate::coordinator::fleet::FleetStats;
use crate::coordinator::pareto::ParetoFront;
use crate::cost::Atlas;
use crate::coordinator::phases::{RegDriverKind, RunResult};
use crate::runtime::AllocStats;
use crate::util::table::{f2, f4, Table};

/// One-line donation / buffer-pool summary. The CI e2e leg greps this
/// exact format ("alloc: donated N ..." and "aliased-fallback 0"), so
/// keep it stable.
pub fn alloc_line(a: &AllocStats) -> String {
    format!(
        "alloc: donated {} pooled {} allocated {} pinned-fallback {} aliased-fallback {}",
        a.donated, a.pooled, a.allocated, a.fallback_pinned, a.fallback_aliased
    )
}

/// One-line shared-cache summary for a `compare`. The CI e2e leg
/// greps exact tokens out of this line — "warmups run N (reused M)",
/// "warmups_loaded N", "warmups_persisted N", "warmup_steps_run N",
/// "split uploads N ", "held_bytes N", "evictions N (", "rebuilds N)"
/// — so keep the format stable.
pub fn cache_line(cr: &CompareResult) -> String {
    format!(
        "shared cache: warmups run {} (reused {}), warmups_loaded {}, \
         warmups_persisted {}, warmup_steps_run {}, split uploads {} (reused {}), \
         held_bytes {}, evictions {} (pinned-skips {}, rebuilds {})",
        cr.warmups_run,
        cr.warmups_reused,
        cr.warmups_loaded,
        cr.warmups_persisted,
        cr.warmup_steps_run,
        cr.split_uploads,
        cr.split_reuses,
        cr.held_bytes,
        cr.evictions,
        cr.evict_skipped_pinned,
        cr.rebuilds_after_evict
    )
}

/// One-line regularizer-driver summary. The CI e2e leg greps the
/// exact "reg driver: artifact(<reg>)" / "reg driver: external(<reg>)"
/// prefix and the "grad_uploads N soft_evals N" counters out of this
/// line, so keep the format stable.
pub fn reg_driver_line(
    kind: RegDriverKind,
    reg: &str,
    grad_uploads: u64,
    soft_evals: u64,
) -> String {
    match kind {
        RegDriverKind::Artifact => format!("reg driver: artifact({reg})"),
        RegDriverKind::External => format!(
            "reg driver: external({reg}) grad_uploads {grad_uploads} soft_evals {soft_evals}"
        ),
    }
}

/// One-line fleet summary for a distributed sweep/compare. The CI
/// chaos leg greps exact tokens out of this line — "expired N",
/// "retries N", "quarantined N" — so keep the format stable.
pub fn fleet_line(fs: &FleetStats) -> String {
    format!(
        "fleet: units {}, completed {}, leases claimed {} (expired {}, stolen {}), \
         retries {}, quarantined {}",
        fs.units,
        fs.completed,
        fs.leases_claimed,
        fs.leases_expired,
        fs.leases_stolen,
        fs.retries,
        fs.quarantined
    )
}

/// Render a set of runs as the standard results table.
pub fn runs_table(title: &str, runs: &[(String, &RunResult)]) -> Table {
    let mut t = Table::new(
        title,
        &[
            "method", "lambda", "val acc", "test acc", "size kB",
            "MPIC Mcyc", "NE16 kcyc", "Gbitops", "time s",
        ],
    );
    for (label, r) in runs {
        t.row(vec![
            label.clone(),
            f4(r.lambda as f64),
            f4(r.val_acc),
            f4(r.test_acc),
            f2(r.size_kb),
            f2(r.mpic_cycles / 1e6),
            f2(r.ne16_cycles / 1e3),
            f2(r.bitops / 1e9),
            f2(r.timing.total_s()),
        ]);
    }
    t
}

/// Paper-style headline: size reduction at iso-accuracy vs a baseline.
/// Returns (reduction fraction, our point cost) when a front point
/// matches or beats `baseline_acc`.
pub fn iso_accuracy_reduction(
    front: &ParetoFront,
    baseline_acc: f64,
    baseline_cost: f64,
) -> Option<(f64, f64)> {
    front
        .iso_accuracy(baseline_acc)
        .map(|p| (1.0 - p.cost / baseline_cost, p.cost))
}

/// Accuracy gain at iso-cost vs a baseline point.
pub fn iso_cost_gain(
    front: &ParetoFront,
    baseline_acc: f64,
    baseline_cost: f64,
) -> Option<(f64, f64)> {
    front
        .iso_cost(baseline_cost)
        .map(|p| (p.acc - baseline_acc, p.acc))
}

/// Pareto front as a printable table.
pub fn front_table(title: &str, front: &ParetoFront, cost_name: &str) -> Table {
    let mut t = Table::new(title, &[cost_name, "val acc", "tag"]);
    for p in front.points() {
        t.row(vec![f2(p.cost), f4(p.acc), p.tag.clone()]);
    }
    t
}

/// One Pareto-front table per atlas target (normalized cost, so the
/// columns line up across targets whose raw units differ). The CI e2e
/// leg greps "atlas front: edge-dsp" out of the rendered titles.
pub fn atlas_tables(atlas: &Atlas) -> Vec<Table> {
    atlas
        .targets
        .iter()
        .map(|t| {
            let mut tab = Table::new(
                &format!("atlas front: {}", t.model),
                &["cost/w8a8", "val acc", "tag"],
            );
            for p in t.front.points() {
                tab.row(vec![f4(p.cost), f4(p.acc), p.tag.clone()]);
            }
            tab
        })
        .collect()
}

/// One-line atlas summary. The CI e2e leg greps the exact
/// "atlas: N targets over P points" prefix, so keep the format stable.
pub fn atlas_line(atlas: &Atlas) -> String {
    let points = atlas.targets.first().map_or(0, |t| t.points);
    let names: Vec<String> = atlas.targets.iter().map(|t| t.model.clone()).collect();
    format!(
        "atlas: {} targets over {} points ({})",
        atlas.len(),
        points,
        names.join(", ")
    )
}

/// Training history CSV (loss curves for the e2e example).
pub fn history_table(r: &RunResult) -> Table {
    let mut t = Table::new(
        &format!("history {} reg={} lam={}", r.model, r.reg, r.lambda),
        &["phase", "step", "loss", "acc", "cost"],
    );
    for rec in &r.history {
        t.row(vec![
            rec.phase.to_string(),
            rec.step.to_string(),
            f4(rec.loss as f64),
            f4(rec.acc as f64),
            if rec.cost.is_nan() {
                "".into()
            } else {
                f4(rec.cost as f64)
            },
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pareto::Point;

    #[test]
    fn iso_helpers() {
        let f = ParetoFront::from_points([
            Point::new(10.0, 0.6, "a"),
            Point::new(20.0, 0.8, "b"),
        ]);
        let (red, cost) = iso_accuracy_reduction(&f, 0.8, 40.0).unwrap();
        assert_eq!(cost, 20.0);
        assert!((red - 0.5).abs() < 1e-12);
        let (gain, acc) = iso_cost_gain(&f, 0.5, 15.0).unwrap();
        assert_eq!(acc, 0.6);
        assert!((gain - 0.1).abs() < 1e-12);
        assert!(iso_accuracy_reduction(&f, 0.9, 40.0).is_none());
    }

    /// The e2e CI leg greps "reg driver: artifact(...)" /
    /// "reg driver: external(...)" and the counters out of these
    /// exact renderings.
    #[test]
    fn reg_driver_line_format() {
        assert_eq!(
            reg_driver_line(RegDriverKind::Artifact, "size", 0, 0),
            "reg driver: artifact(size)"
        );
        assert_eq!(
            reg_driver_line(RegDriverKind::External, "edge-dsp", 40, 40),
            "reg driver: external(edge-dsp) grad_uploads 40 soft_evals 40"
        );
    }

    /// The chaos CI leg greps "expired N", "retries N" and
    /// "quarantined N" out of this exact rendering.
    #[test]
    fn fleet_line_format() {
        let fs = FleetStats {
            units: 12,
            completed: 12,
            leases_claimed: 14,
            leases_expired: 2,
            leases_stolen: 1,
            retries: 3,
            quarantined: 0,
        };
        assert_eq!(
            fleet_line(&fs),
            "fleet: units 12, completed 12, leases claimed 14 (expired 2, stolen 1), \
             retries 3, quarantined 0"
        );
    }
}
