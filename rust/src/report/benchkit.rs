//! Shared scaffolding for the figure/table bench harnesses
//! (`rust/benches/*.rs`, `harness = false`).
//!
//! Each harness regenerates one paper table/figure at *bench scale*
//! (synthetic data, shortened phases — the testbed has a single CPU
//! core; see DESIGN.md Sec. 3/4). Scale knobs come from env vars so
//! `cargo bench` stays bounded while `MIXPREC_FULL=1` runs the long
//! version:
//!
//! * `MIXPREC_WARMUP` / `MIXPREC_STEPS` / `MIXPREC_FINETUNE`
//! * `MIXPREC_POINTS`   — lambda points per sweep
//! * `MIXPREC_DATA_FRAC`
//! * `MIXPREC_WORKERS`
//! * `MIXPREC_SWEEP_MODE=forked|independent` — warmup sharing across
//!   sweep lambdas (default forked: one shared warmup phase)
//! * `MIXPREC_VARY_SEEDS=1` — independent mode only: distinct seed
//!   per lambda (the pre-fork legacy sweep behavior)
//! * `MIXPREC_BATCHED_EVAL=0` — fall back to the per-batch eval loop
//! * `MIXPREC_SHARE_EVAL=0` — disable the shared eval-split cache
//!   (each run uploads its own splits, the pre-cache behavior)
//! * `MIXPREC_SHARE_WARMUP=0` — disable the cross-method `WarmStart`
//!   pool (each sweep warms up itself)
//! * `MIXPREC_WARM_DIR` — attach the cross-process warm-start disk
//!   tier: warmups persist here and later processes resume from them
//!   with zero warmup steps (unset: in-memory sharing only)
//! * `MIXPREC_CACHE_BUDGET_BYTES` — byte budget of the in-process
//!   shared cache (eval splits + warm starts, default 256 MiB, 0 =
//!   unlimited): LRU entries no live run holds are evicted and rebuilt
//!   on demand, bitwise identically
//! * `MIXPREC_HOST_RESIDENT=1` — force the seed's per-step full
//!   host<->device marshal (baseline for the step-marshalling bench)
//! * `MIXPREC_XLA_THREADS` — backend execution threads (default:
//!   available parallelism; `1` pins the sequential path — results
//!   are bitwise identical at any count, only throughput changes)
//! * `MIXPREC_BENCH_DIR` — where `BENCH_*.json` trend files land
//!   (default: current directory)

use std::path::PathBuf;
use std::time::Instant;

use crate::coordinator::{Context, PipelineConfig, Runner, SweepMode, SweepOptions, TempSchedule};
use crate::error::Result;
use crate::util::json::Json;

fn env_usize(key: &str, default: usize) -> usize {
    crate::util::env_parsed(key).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    crate::util::env_parsed(key).unwrap_or(default)
}

#[derive(Debug, Clone)]
pub struct BenchScale {
    pub warmup: usize,
    pub steps: usize,
    pub finetune: usize,
    pub points: usize,
    pub data_frac: f64,
    pub workers: usize,
    pub sweep_mode: SweepMode,
    pub vary_seeds: bool,
    pub batched_eval: bool,
    pub host_resident: bool,
    /// Share eval-split uploads through the context cache
    /// (`MIXPREC_SHARE_EVAL`, default on).
    pub share_eval: bool,
    /// Share warmups across matching sweeps (`MIXPREC_SHARE_WARMUP`,
    /// default on).
    pub share_warmup: bool,
    /// Cross-process warm-start disk tier (`MIXPREC_WARM_DIR`; unset
    /// keeps the warm pool in-memory only).
    pub warm_dir: Option<PathBuf>,
}

impl BenchScale {
    pub fn from_env() -> Self {
        let full = std::env::var("MIXPREC_FULL").is_ok();
        let (w, s, f, p, d) = if full {
            (300, 400, 120, 7, 1.0)
        } else {
            (48, 96, 24, 3, 0.15)
        };
        // an unparseable value must fail loudly, not silently change
        // which science the figure harnesses run
        let sweep_mode = match std::env::var("MIXPREC_SWEEP_MODE") {
            Ok(v) => SweepMode::parse(&v).unwrap_or_else(|| {
                panic!("MIXPREC_SWEEP_MODE='{v}' (expected forked|independent)")
            }),
            Err(_) => SweepMode::default(),
        };
        BenchScale {
            warmup: env_usize("MIXPREC_WARMUP", w),
            steps: env_usize("MIXPREC_STEPS", s),
            finetune: env_usize("MIXPREC_FINETUNE", f),
            points: env_usize("MIXPREC_POINTS", p),
            data_frac: env_f64("MIXPREC_DATA_FRAC", d),
            workers: env_usize("MIXPREC_WORKERS", 1),
            sweep_mode,
            vary_seeds: env_usize("MIXPREC_VARY_SEEDS", 0) != 0,
            batched_eval: env_usize("MIXPREC_BATCHED_EVAL", 1) != 0,
            host_resident: env_usize("MIXPREC_HOST_RESIDENT", 0) != 0,
            share_eval: env_usize("MIXPREC_SHARE_EVAL", 1) != 0,
            share_warmup: env_usize("MIXPREC_SHARE_WARMUP", 1) != 0,
            warm_dir: std::env::var("MIXPREC_WARM_DIR").ok().map(PathBuf::from),
        }
    }

    pub fn config(&self, model: &str) -> PipelineConfig {
        let mut cfg = PipelineConfig::quick(model);
        cfg.warmup_steps = self.warmup;
        cfg.search_steps = self.steps;
        cfg.finetune_steps = self.finetune;
        cfg.data_frac = self.data_frac;
        cfg.host_resident = self.host_resident;
        cfg.batched_eval = self.batched_eval;
        cfg.eval_every = (self.steps / 3).max(8);
        cfg.steps_per_epoch = 16;
        // keep the same *final* temperature despite the short schedule,
        // as the paper does for Tiny ImageNet (Sec. 5.1.1)
        cfg.temp = TempSchedule::rescaled(self.steps / 16, 200);
        cfg
    }

    /// Sweep scheduling knobs for the figure harnesses.
    pub fn sweep_opts(&self) -> SweepOptions {
        SweepOptions {
            workers: self.workers,
            mode: self.sweep_mode,
            vary_seeds: self.vary_seeds,
            share_warmup: self.share_warmup,
        }
    }

    /// Model runner for a figure harness, from the independent
    /// `MIXPREC_SHARE_EVAL` / `MIXPREC_SHARE_WARMUP` knobs (warm-pool
    /// *use* is governed per sweep via [`BenchScale::sweep_opts`]; the
    /// attach-or-not rule lives in `Context::runner_with_sharing`).
    /// `MIXPREC_WARM_DIR` attaches the warm-start disk tier to the
    /// context's cache.
    pub fn runner<'a>(&self, ctx: &'a Context, model: &str) -> Result<Runner<'a>> {
        ctx.shared_cache().set_warm_dir(self.warm_dir.clone());
        ctx.runner_with_sharing(model, self.share_eval, self.share_warmup)
    }
}

/// Where `BENCH_<name>.json` trend files are written
/// (`MIXPREC_BENCH_DIR`, default current directory).
pub fn bench_json_path(name: &str) -> PathBuf {
    let dir = std::env::var("MIXPREC_BENCH_DIR").unwrap_or_else(|_| ".".into());
    PathBuf::from(dir).join(format!("BENCH_{name}.json"))
}

/// Write a bench payload as pretty-printed JSON so the perf
/// trajectory is tracked across PRs (`BENCH_step_marshal.json` etc.).
pub fn write_bench_json(name: &str, payload: &Json) -> Result<PathBuf> {
    let path = bench_json_path(name);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&path, payload.to_string_pretty())?;
    println!("wrote {}", path.display());
    Ok(path)
}

/// Bench harness entrypoint: prints a banner, loads the context, runs
/// the body, prints elapsed. Skips gracefully when artifacts are
/// missing (so `cargo bench` works pre-`make artifacts` in CI dry
/// runs).
pub fn run_bench(name: &str, body: impl FnOnce(&Context, &BenchScale) -> Result<()>) {
    // `cargo bench` passes harness flags; ignore them.
    let scale = BenchScale::from_env();
    println!("=== {name} (scale: {scale:?}) ===");
    let dir = Context::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP: no artifacts at {dir:?}; run `make artifacts` first");
        return;
    }
    let t0 = Instant::now();
    let ctx = match Context::load(&dir, scale.data_frac) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("SKIP: context load failed: {e}");
            return;
        }
    };
    match body(&ctx, &scale) {
        Ok(()) => println!("=== {name} done in {:.1}s ===", t0.elapsed().as_secs_f64()),
        Err(e) => {
            eprintln!("{name} FAILED: {e}");
            std::process::exit(1);
        }
    }
}
