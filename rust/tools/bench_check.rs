//! CI bench-regression gate.
//!
//! Compares freshly produced `BENCH_<name>.json` trend files (written
//! by the bench harnesses) against committed
//! `BENCH_<name>.baseline.json` files and fails on regression. Only
//! *deterministic* counters are gated by default — bytes per step,
//! donation/pool counts, warmup phases run/saved, split uploads,
//! equivalence booleans — never wall-clock, which is noise on shared
//! CI runners. One exception is opt-in: with
//! `MIXPREC_GATE_THROUGHPUT=1` (a dedicated CI leg on a quiet runner)
//! the device leg's `steps_per_sec` is gated with a loose 0.5x
//! tolerance, so a wall-clock collapse fails loudly too. The
//! throughput key only enters a baseline when `--update` runs with the
//! variable set.
//!
//! The baseline may carry a *subset* of the rule keys: a rule whose
//! baseline key is absent is reported as skipped (committed baselines
//! start conservative and tighten via `--update`). A rule whose
//! *current* key is absent fails — a gated counter disappearing is
//! itself a regression.
//!
//! ```sh
//! cargo run --release --bin bench_check                # gate step_marshal + sweep_fork
//! cargo run --release --bin bench_check -- sweep_fork  # gate one bench
//! cargo run --release --bin bench_check -- --update    # refresh the gated keys in the
//!                                                      # baselines from the current run
//! ```
//!
//! Options: `--bench-dir <d>` (where `BENCH_*.json` live, default `.`,
//! matching the benches' `MIXPREC_BENCH_DIR` default), `--baseline-dir
//! <d>` (where `BENCH_*.baseline.json` live, default `.`).

use std::path::{Path, PathBuf};
use std::process::exit;

use mixprec::util::cli::Args;
use mixprec::util::json::{Json, JsonObj};

/// Which way a counter is allowed to move.
#[derive(Clone, Copy, PartialEq)]
enum Dir {
    /// Regression = current above baseline (bytes, uploads, phases).
    LowerIsBetter,
    /// Regression = current below baseline (savings, reuse counts).
    HigherIsBetter,
    /// Must match the baseline exactly (equivalence booleans).
    Exact,
}

struct Rule {
    bench: &'static str,
    /// JSON path into the bench payload.
    path: &'static [&'static str],
    dir: Dir,
    /// Relative tolerance for the numeric directions (0.10 = 10%).
    tol: f64,
    /// Opt-in rules: gated only when this env var is set to "1"
    /// (e.g. the loose throughput rule on a dedicated CI leg).
    env: Option<&'static str>,
}

impl Rule {
    fn enabled(&self) -> bool {
        match self.env {
            None => true,
            Some(var) => matches!(std::env::var(var).as_deref(), Ok("1")),
        }
    }
}

/// The gated counters. All are deterministic on the stub backend at
/// fixed scale; tolerances leave room for benign drift (e.g. a new
/// scalar knob adding a few bytes per step) while catching a real
/// regression such as losing device residency or re-uploading per
/// fork.
const RULES: &[Rule] = &[
    // step_marshal: the device-resident path must keep per-step
    // traffic tiny (a host-resident regression is ~60x these numbers)
    Rule {
        bench: "step_marshal",
        path: &["device", "h2d_bytes_per_step"],
        dir: Dir::LowerIsBetter,
        tol: 0.10,
        env: None,
    },
    Rule {
        bench: "step_marshal",
        path: &["device", "d2h_bytes_per_step"],
        dir: Dir::LowerIsBetter,
        tol: 0.10,
        env: None,
    },
    // donation + pool: the steady-state step loop must stay
    // allocation-free (every state leaf donated, metrics pooled) and
    // never fall back outside snapshot windows
    Rule {
        bench: "step_marshal",
        path: &["device", "buffers_allocated_per_step"],
        dir: Dir::LowerIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "step_marshal",
        path: &["device", "donated_per_step"],
        dir: Dir::HigherIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "step_marshal",
        path: &["device", "pooled_per_step"],
        dir: Dir::HigherIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "step_marshal",
        path: &["device", "fallback_pinned_per_step"],
        dir: Dir::LowerIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "step_marshal",
        path: &["device", "fallback_aliased_per_step"],
        dir: Dir::LowerIsBetter,
        tol: 0.0,
        env: None,
    },
    // zero-copy untuple: the bench's fixed 64-call loop must keep
    // avoiding the element deep-clones
    Rule {
        bench: "step_marshal",
        path: &["untuple_bytes_saved"],
        dir: Dir::HigherIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "step_marshal",
        path: &["sections_equal"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    // opt-in wall-clock gate: device steps/sec within 0.5x of baseline
    // (dedicated CI leg; see module docs)
    Rule {
        bench: "step_marshal",
        path: &["device", "steps_per_sec"],
        dir: Dir::HigherIsBetter,
        tol: 0.5,
        env: Some("MIXPREC_GATE_THROUGHPUT"),
    },
    // batched-eval scoring throughput from the kernel-level leg (same
    // quiet-runner opt-in and loose tolerance as steps_per_sec)
    Rule {
        bench: "step_marshal",
        path: &["device", "eval_chunks_per_sec"],
        dir: Dir::HigherIsBetter,
        tol: 0.5,
        env: Some("MIXPREC_GATE_THROUGHPUT"),
    },
    // sweep_fork: warmup sharing within a sweep
    Rule {
        bench: "sweep_fork",
        path: &["warmup_steps_saved"],
        dir: Dir::HigherIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["forked", "warmup_steps_run"],
        dir: Dir::LowerIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["forked", "fallback_aliased"],
        dir: Dir::LowerIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["fronts_equal"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    // batched eval traffic: cached calls move only the two scalars
    Rule {
        bench: "sweep_fork",
        path: &["eval_bytes_per_call", "batched_cached_call", "h2d_bytes"],
        dir: Dir::LowerIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["eval_bytes_per_call", "batched_first_call", "h2d_bytes"],
        dir: Dir::LowerIsBetter,
        tol: 0.10,
        env: None,
    },
    // compare-level sharing: one warmup, one upload per split
    Rule {
        bench: "sweep_fork",
        path: &["compare", "warmups_run"],
        dir: Dir::LowerIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["compare", "warmups_reused"],
        dir: Dir::HigherIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["compare", "split_uploads"],
        dir: Dir::LowerIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["compare", "split_reuses"],
        dir: Dir::HigherIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["compare", "fronts_equal_unshared"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    // the unbudgeted compare must never evict cache entries
    Rule {
        bench: "sweep_fork",
        path: &["compare", "evictions"],
        dir: Dir::LowerIsBetter,
        tol: 0.0,
        env: None,
    },
    // tiny-budget eviction leg: churn must actually happen (counts are
    // conservative lower bounds — the exact number tracks the working
    // set and is brittle), stay inside the byte cap, and reproduce the
    // unbudgeted front bitwise
    Rule {
        bench: "sweep_fork",
        path: &["eviction", "evictions"],
        dir: Dir::HigherIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["eviction", "rebuilds_after_evict"],
        dir: Dir::HigherIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["eviction", "within_budget"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["eviction", "fronts_equal_unbudgeted"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    // cross-process warm starts: the persisting run writes exactly one
    // disk entry, the resuming run loads it, runs ZERO warmup steps,
    // and reproduces the front bitwise
    Rule {
        bench: "sweep_fork",
        path: &["warm_persist", "warmups_persisted"],
        dir: Dir::HigherIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["warm_persist", "warmups_loaded"],
        dir: Dir::HigherIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["warm_persist", "resume_warmup_steps_run"],
        dir: Dir::LowerIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["warm_persist", "fronts_equal"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    // multi-target Pareto atlas: every registered target gets a front
    // and the scoring stays a pure post-pass (cache untouched, compare
    // counters and fronts identical to the single-model run)
    Rule {
        bench: "sweep_fork",
        path: &["atlas", "targets"],
        dir: Dir::HigherIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["atlas", "points_per_target"],
        dir: Dir::HigherIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["atlas", "includes_lut"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["atlas", "cache_untouched"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["atlas", "warmups_identical"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["atlas", "split_uploads_identical"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["atlas", "steps_identical"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["atlas", "fronts_equal_single_model"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    // fleet leg: every unit completes, claims sum to the unit count
    // (exactly-once across the coordinator/worker race — the split
    // itself is nondeterministic and not gated), the healthy path
    // never retries or quarantines, and the merged front is bitwise
    // identical to the single-process sweep
    Rule {
        bench: "sweep_fork",
        path: &["fleet", "units"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["fleet", "completed"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["fleet", "claims_total"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["fleet", "retries"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["fleet", "quarantined"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["fleet", "fronts_equal"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    // external regularizer driver (edge-dsp-driven search): the host
    // side must evaluate the soft surface and upload a gradient every
    // search step (counts are conservative lower bounds — the exact
    // number tracks search_steps and early stopping), every soft eval
    // pairs with exactly one upload, the builtin artifact drivers keep
    // both counters at zero, the driving model's discrete cost is live
    // on every external run, and the tailored search matches or beats
    // the size-driven one under its own target
    Rule {
        bench: "sweep_fork",
        path: &["extgrad", "grad_uploads"],
        dir: Dir::HigherIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["extgrad", "soft_evals"],
        dir: Dir::HigherIsBetter,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["extgrad", "grads_match_evals"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["extgrad", "artifact_counters_zero"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["extgrad", "ext_cost_live"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    Rule {
        bench: "sweep_fork",
        path: &["extgrad", "front_matches_size_under_target"],
        dir: Dir::Exact,
        tol: 0.0,
        env: None,
    },
    // opt-in wall-clock gate: per-step host grad + upload overhead of
    // the external driver vs the artifact driver (quiet-runner CI leg,
    // same opt-in as the step_marshal throughput gates)
    Rule {
        bench: "sweep_fork",
        path: &["extgrad", "overhead_vs_artifact"],
        dir: Dir::LowerIsBetter,
        tol: 1.0,
        env: Some("MIXPREC_GATE_THROUGHPUT"),
    },
];

const DEFAULT_BENCHES: [&str; 2] = ["step_marshal", "sweep_fork"];

fn lookup<'a>(mut v: &'a Json, path: &[&str]) -> Option<&'a Json> {
    for key in path {
        match v.as_obj().and_then(|o| o.get(key)) {
            Some(next) => v = next,
            None => return None,
        }
    }
    Some(v)
}

fn load(path: &Path) -> Option<Json> {
    let text = std::fs::read_to_string(path).ok()?;
    match Json::parse(&text) {
        Ok(v) => Some(v),
        Err(e) => {
            eprintln!("bench_check: {} is not valid JSON: {e}", path.display());
            None
        }
    }
}

fn fmt_path(path: &[&str]) -> String {
    path.join(".")
}

/// Set a nested key path, creating intermediate objects as needed
/// (insertion order — and therefore the committed baseline's diff
/// stability — is preserved by `JsonObj`).
fn set_path(v: &mut Json, path: &[&str], val: Json) {
    if path.is_empty() {
        *v = val;
        return;
    }
    if !matches!(v, Json::Obj(_)) {
        *v = Json::Obj(JsonObj::new());
    }
    if let Json::Obj(o) = v {
        let mut child = o.get(path[0]).cloned().unwrap_or(Json::Null);
        set_path(&mut child, &path[1..], val);
        o.insert(path[0], child);
    }
}

/// `--update`: refresh only the *gated* keys in the baseline, starting
/// from the existing file when there is one — hand-written headroom
/// notes (`_comment`) and any other curated keys survive, and noisy
/// ungated fields (wall-clock seconds) never enter the baseline. The
/// written values are exact measurements; re-add ceiling headroom by
/// hand where the old baseline had it.
fn updated_baseline(name: &str, cur: &Json, existing: Option<Json>) -> Json {
    let mut base = existing.unwrap_or_else(|| {
        let mut o = JsonObj::new();
        o.insert("bench", Json::Str(name.into()));
        Json::Obj(o)
    });
    for rule in RULES.iter().filter(|r| r.bench == name) {
        // An env-gated key is written only while its leg is enabled:
        // a plain --update on a developer machine must not clobber a
        // baseline measured on the dedicated (quiet) runner. A
        // bootstrapped key that is skipped is called out loudly so it
        // cannot go stale silently either.
        if !rule.enabled() {
            if lookup(&base, rule.path).is_some() {
                eprintln!(
                    "  WARN [{name}] left {} untouched ({} != 1); refresh it on \
                     the dedicated leg if this update changes wall-clock",
                    fmt_path(rule.path),
                    rule.env.unwrap_or("?")
                );
            }
            continue;
        }
        if let Some(v) = lookup(cur, rule.path) {
            set_path(&mut base, rule.path, v.clone());
        }
    }
    base
}

/// One rule against one (current, baseline) pair. Returns Err(reason)
/// on regression, Ok(Some(note)) on skip, Ok(None) on pass.
fn check(rule: &Rule, cur: &Json, base: &Json) -> Result<Option<String>, String> {
    let key = fmt_path(rule.path);
    let Some(b) = lookup(base, rule.path) else {
        return Ok(Some(format!("skip {key} (not in baseline)")));
    };
    let Some(c) = lookup(cur, rule.path) else {
        return Err(format!("{key}: present in baseline but missing from current run"));
    };
    match rule.dir {
        Dir::Exact => {
            if c == b {
                Ok(None)
            } else {
                Err(format!("{key}: expected {b}, got {c}"))
            }
        }
        Dir::LowerIsBetter | Dir::HigherIsBetter => {
            let (Some(cv), Some(bv)) = (c.as_f64(), b.as_f64()) else {
                return Err(format!("{key}: expected numbers, got {c} vs baseline {b}"));
            };
            let slack = bv.abs() * rule.tol;
            let regressed = match rule.dir {
                Dir::LowerIsBetter => cv > bv + slack,
                Dir::HigherIsBetter => cv < bv - slack,
                Dir::Exact => unreachable!(),
            };
            if regressed {
                let (cmp, limit) = match rule.dir {
                    Dir::LowerIsBetter => ("<=", bv + slack),
                    _ => (">=", bv - slack),
                };
                Err(format!(
                    "{key}: {cv} (baseline {bv}, tolerance {:.0}%, want {cmp} {limit:.2})",
                    rule.tol * 100.0
                ))
            } else {
                Ok(None)
            }
        }
    }
}

fn main() {
    let a = Args::from_env();
    let bench_dir = PathBuf::from(a.str_or("bench-dir", "."));
    let baseline_dir = PathBuf::from(a.str_or("baseline-dir", "."));
    let update = a.has("update");
    let mut benches: Vec<String> = Vec::new();
    let mut i = 0;
    while let Some(p) = a.pos(i) {
        benches.push(p.to_string());
        i += 1;
    }
    if benches.is_empty() {
        benches = DEFAULT_BENCHES.iter().map(|s| s.to_string()).collect();
    }

    let mut failures = 0usize;
    for name in &benches {
        let cur_path = bench_dir.join(format!("BENCH_{name}.json"));
        let base_path = baseline_dir.join(format!("BENCH_{name}.baseline.json"));
        let Some(cur) = load(&cur_path) else {
            eprintln!(
                "FAIL [{name}] no current trend file at {} (did the bench leg run?)",
                cur_path.display()
            );
            failures += 1;
            continue;
        };
        if update {
            let merged = updated_baseline(name, &cur, load(&base_path));
            std::fs::write(&base_path, merged.to_string_pretty())
                .unwrap_or_else(|e| panic!("write {}: {e}", base_path.display()));
            println!("updated gated keys in {}", base_path.display());
            continue;
        }
        let Some(base) = load(&base_path) else {
            eprintln!(
                "FAIL [{name}] no baseline at {} (bootstrap with --update and commit it)",
                base_path.display()
            );
            failures += 1;
            continue;
        };
        let mut bench_failures = 0usize;
        for rule in RULES.iter().filter(|r| r.bench == name) {
            if !rule.enabled() {
                println!(
                    "  note [{name}] skip {} ({}!=1)",
                    fmt_path(rule.path),
                    rule.env.unwrap_or("?")
                );
                continue;
            }
            match check(rule, &cur, &base) {
                Ok(None) => println!("  ok   [{name}] {}", fmt_path(rule.path)),
                Ok(Some(note)) => println!("  note [{name}] {note}"),
                Err(reason) => {
                    eprintln!("  FAIL [{name}] {reason}");
                    bench_failures += 1;
                }
            }
        }
        if bench_failures == 0 {
            println!("PASS [{name}]");
        } else {
            eprintln!("FAIL [{name}] {bench_failures} regressed counter(s)");
            failures += bench_failures;
        }
    }
    if failures > 0 {
        eprintln!(
            "bench_check: {failures} regression(s). If intentional, refresh the \
             baselines with `cargo run --release --bin bench_check -- --update` \
             and commit the BENCH_*.baseline.json changes."
        );
        exit(1);
    }
    println!("bench_check: all gated counters within tolerance");
}
