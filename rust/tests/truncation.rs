//! Torn-write crash matrix at the container level (ISSUE 9
//! satellite): every strict prefix of a valid v2 checkpoint (with
//! extras) must fail [`load_with_extras`] with a clean error — never
//! a panic, never partial state — and every strict prefix of a fleet
//! result file must make [`read_result_file`] return `None`. This is
//! the property that lets a torn warm checkpoint degrade to a fresh
//! warmup and a torn result file degrade to a requeue.

use std::path::PathBuf;

use mixprec::assignment::Assignment;
use mixprec::coordinator::checkpoint::{load_with_extras, save_with_extras_atomic};
use mixprec::coordinator::fleet::{read_result_file, write_result_file, WorkUnit};
use mixprec::coordinator::{
    PipelineConfig, Record, RegDriverKind, RunResult, Sampling, Timing,
};
use mixprec::runtime::{fixture, AllocStats, TrainState, TransferStats};
use mixprec::util::tensor::Tensor;

struct Tmp(PathBuf);

impl Tmp {
    fn new(tag: &str) -> Tmp {
        let dir = std::env::temp_dir().join(format!(
            "mixprec_trunc_{tag}_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        Tmp(dir)
    }
}

impl Drop for Tmp {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.0).ok();
    }
}

fn sample_state() -> TrainState {
    let mut st = TrainState::default();
    st.sections.insert(
        "params".into(),
        vec![Tensor::scalar_f32(1.5), Tensor::scalar_f32(-2.0)],
    );
    st.sections.insert("opt".into(), vec![Tensor::scalar_f32(0.25)]);
    st
}

/// Every strict prefix of a v2 checkpoint-with-extras fails cleanly;
/// only the complete file decodes. Covers the shared warm checkpoint:
/// `try_load_warm` feeds torn files through this exact decoder.
#[test]
fn every_checkpoint_prefix_fails_cleanly() {
    let tmp = Tmp::new("ckpt");
    let path = tmp.0.join("state.ckpt");
    let extras: Vec<(&str, Vec<u8>)> = vec![
        ("alpha", b"abc".to_vec()),
        ("beta", vec![0u8; 33]),
        ("empty", Vec::new()),
    ];
    save_with_extras_atomic(&sample_state(), &extras, &path).unwrap();

    let (st, ex) = load_with_extras(&path).expect("the complete file must load");
    assert_eq!(st.sections.len(), 2);
    assert_eq!(ex.len(), 3);
    let find = |name: &str| ex.iter().find(|(n, _)| n == name).map(|(_, b)| b.clone());
    assert_eq!(find("alpha").unwrap(), b"abc".to_vec());
    assert_eq!(find("beta").unwrap().len(), 33);
    assert_eq!(find("empty").unwrap(), Vec::<u8>::new());

    let full = std::fs::read(&path).unwrap();
    assert!(full.len() > 64, "fixture file should be non-trivial");
    let torn = tmp.0.join("torn.ckpt");
    for cut in 0..full.len() {
        std::fs::write(&torn, &full[..cut]).unwrap();
        assert!(
            load_with_extras(&torn).is_err(),
            "prefix of {cut}/{} bytes decoded as a complete checkpoint",
            full.len()
        );
    }
}

fn sample_run() -> RunResult {
    RunResult {
        model: fixture::STUB_MODEL.to_string(),
        reg: "edge-dsp".to_string(),
        // external driver with live counters: the roundtrip must carry
        // the driver tag and both counters, not re-derive them
        reg_driver: RegDriverKind::External,
        lambda: 0.5,
        sampling: Sampling::Gumbel,
        val_acc: 0.875,
        test_acc: 0.8125,
        assignment: Assignment {
            gamma_bits: vec![vec![8, 4, 0], vec![2]],
            delta_bits: vec![8, 4],
        },
        size_kb: 12.5,
        mpic_cycles: 1.0e6,
        ne16_cycles: 2.0e5,
        bitops: 3.5e9,
        ext_cost: 6.25e4,
        // a NaN cost rides in the warmup record on purpose: the
        // roundtrip must preserve it bitwise, not normalize it
        history: vec![
            Record { phase: "warmup", step: 1, loss: 2.5, acc: 0.25, cost: f32::NAN },
            Record { phase: "search", step: 2, loss: 1.25, acc: 0.5, cost: 42.0 },
            Record { phase: "finetune", step: 3, loss: 0.75, acc: 0.875, cost: 41.0 },
        ],
        timing: Timing { warmup_s: 1.0, search_s: 2.0, finetune_s: 0.5 },
        steps_run: 30,
        soft_evals: 30,
        grad_uploads: 30,
        transfer: TransferStats { h2d_bytes: 1, d2h_bytes: 2, h2d_tensors: 3, d2h_tensors: 4 },
        alloc: AllocStats {
            donated: 5,
            pooled: 6,
            allocated: 7,
            fallback_pinned: 8,
            fallback_aliased: 9,
        },
    }
}

/// A fleet result file roundtrips bitwise; every strict prefix fails
/// the container decode AND reads back as `None` (the merge loop's
/// requeue path), and garbage bytes read as `None` too.
#[test]
fn every_result_file_prefix_reads_as_none() {
    let tmp = Tmp::new("result");
    let unit = WorkUnit {
        id: 0xfeed_beef_dead_cafe,
        label: "sweep".to_string(),
        index: 0,
        lambda: 0.5,
        cfg: PipelineConfig::quick(fixture::STUB_MODEL),
    };
    let run = sample_run();
    let path = tmp.0.join("result.ckpt");
    write_result_file(&path, 0x1234_5678, &unit, "owner-a", &run).unwrap();

    let (meta, back) = read_result_file(&path).expect("the complete file must decode");
    assert_eq!((meta.unit_id, meta.job_fp), (unit.id, 0x1234_5678));
    assert_eq!(meta.owner, "owner-a");
    assert_eq!(meta.label, "sweep");
    assert_eq!(meta.index, 0);
    assert_eq!(meta.lambda_bits, unit.lambda.to_bits());
    assert_eq!(back.model, run.model);
    assert_eq!(back.reg, run.reg);
    assert_eq!(back.lambda.to_bits(), run.lambda.to_bits());
    assert_eq!(back.sampling, run.sampling);
    assert_eq!(back.val_acc.to_bits(), run.val_acc.to_bits());
    assert_eq!(back.test_acc.to_bits(), run.test_acc.to_bits());
    assert_eq!(back.assignment, run.assignment);
    assert_eq!(back.size_kb.to_bits(), run.size_kb.to_bits());
    assert_eq!(back.mpic_cycles.to_bits(), run.mpic_cycles.to_bits());
    assert_eq!(back.ne16_cycles.to_bits(), run.ne16_cycles.to_bits());
    assert_eq!(back.bitops.to_bits(), run.bitops.to_bits());
    assert_eq!(back.ext_cost.to_bits(), run.ext_cost.to_bits());
    assert_eq!(back.reg_driver, run.reg_driver);
    assert_eq!(back.steps_run, run.steps_run);
    assert_eq!(back.soft_evals, run.soft_evals);
    assert_eq!(back.grad_uploads, run.grad_uploads);
    assert_eq!(back.history.len(), run.history.len());
    for (a, b) in back.history.iter().zip(&run.history) {
        assert_eq!((a.phase, a.step), (b.phase, b.step));
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.acc.to_bits(), b.acc.to_bits());
        assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "NaN cost must roundtrip bitwise");
    }
    assert_eq!(back.timing.warmup_s.to_bits(), run.timing.warmup_s.to_bits());
    assert_eq!(back.timing.search_s.to_bits(), run.timing.search_s.to_bits());
    assert_eq!(back.timing.finetune_s.to_bits(), run.timing.finetune_s.to_bits());
    assert_eq!(back.transfer, run.transfer);
    assert_eq!(back.alloc, run.alloc);

    let full = std::fs::read(&path).unwrap();
    let torn = tmp.0.join("torn.ckpt");
    for cut in 0..full.len() {
        std::fs::write(&torn, &full[..cut]).unwrap();
        assert!(
            load_with_extras(&torn).is_err(),
            "prefix of {cut}/{} bytes decoded as a complete container",
            full.len()
        );
        assert!(
            read_result_file(&torn).is_none(),
            "prefix of {cut}/{} bytes produced a result",
            full.len()
        );
    }

    // garbage and foreign bytes degrade to None the same way
    std::fs::write(&torn, b"complete garbage, definitely not a checkpoint").unwrap();
    assert!(read_result_file(&torn).is_none());
}
