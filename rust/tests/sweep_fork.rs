//! Shared-warmup forked sweeps + batched device-resident eval, tested
//! end-to-end on the stub fixture (`runtime::fixture`), which now
//! ships every artifact the pipeline binds — so `Runner::run`,
//! `run_from` forks and both eval paths execute for real without AOT
//! artifacts or native XLA.
//!
//! Asserts the tentpole contract:
//! (a) `ForkedWarmup` and `Independent` sweeps are bitwise identical
//!     for the same seeds (assignments, accuracies, history, front);
//! (b) a forked sweep executes the warmup exactly once (step counters
//!     + transfer stats);
//! (c) batched eval matches per-batch eval exactly — ragged final
//!     chunk included — while moving strictly fewer bytes.

use std::path::PathBuf;

use mixprec::assignment::PrecisionMasks;
use mixprec::coordinator::{
    sweep_lambdas, Context, EvalBufs, MaskBufs, PipelineConfig, SweepMode,
    SweepOptions,
};
use mixprec::data::Split;
use mixprec::runtime::{fixture, DeviceState, StepFn, TransferStats};

struct Fx {
    dir: PathBuf,
    ctx: Context,
}

impl Fx {
    /// data_frac 0.07 -> n_val = n_test = 35, deliberately not a
    /// multiple of the fixture batch (8) so every eval path covers a
    /// ragged (padded) final chunk.
    fn new(tag: &str) -> Fx {
        let dir = std::env::temp_dir().join(format!(
            "mixprec_sweepfork_{tag}_{}",
            std::process::id()
        ));
        fixture::write_stub_fixture(&dir).expect("fixture");
        let ctx = Context::load(&dir, 0.07).expect("context");
        Fx { dir, ctx }
    }
}

impl Drop for Fx {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

fn quick_cfg() -> PipelineConfig {
    let mut cfg = PipelineConfig::quick(fixture::STUB_MODEL);
    cfg.warmup_steps = 12;
    cfg.search_steps = 24;
    cfg.finetune_steps = 6;
    cfg.eval_every = 8;
    cfg.steps_per_epoch = 8;
    cfg
}

fn opts(mode: SweepMode, workers: usize) -> SweepOptions {
    SweepOptions {
        workers,
        mode,
        // shared seed in both modes: the equivalence baseline
        vary_seeds: false,
        // irrelevant here: these runners carry no shared cache
        share_warmup: true,
    }
}

const LAMBDAS: [f64; 3] = [0.05, 0.5, 5.0];

/// Bitwise history comparison (warmup records carry a NaN cost, so
/// `PartialEq` on f32 would treat identical trajectories as unequal).
fn assert_history_eq(a: &[mixprec::coordinator::Record], b: &[mixprec::coordinator::Record]) {
    assert_eq!(a.len(), b.len(), "history length diverged");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.phase, y.phase);
        assert_eq!(x.step, y.step);
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{}[{}] loss", x.phase, x.step);
        assert_eq!(x.acc.to_bits(), y.acc.to_bits(), "{}[{}] acc", x.phase, x.step);
        assert_eq!(x.cost.to_bits(), y.cost.to_bits(), "{}[{}] cost", x.phase, x.step);
    }
}

/// (a) Forked and independent sweeps are bitwise identical when they
/// share seeds — same assignments, accuracies, histories, fronts.
#[test]
fn forked_sweep_matches_independent_bitwise() {
    let fx = Fx::new("equiv");
    let runner = fx.ctx.runner(fixture::STUB_MODEL).unwrap();
    let cfg = quick_cfg();
    let forked = sweep_lambdas(
        &runner,
        &cfg,
        &LAMBDAS,
        "size",
        &opts(SweepMode::ForkedWarmup, 1),
    )
    .unwrap();
    let indep = sweep_lambdas(
        &runner,
        &cfg,
        &LAMBDAS,
        "size",
        &opts(SweepMode::Independent, 1),
    )
    .unwrap();
    assert_eq!(forked.runs.len(), indep.runs.len());
    for (f, i) in forked.runs.iter().zip(&indep.runs) {
        assert_eq!(f.lambda, i.lambda);
        assert_eq!(f.assignment, i.assignment, "assignment diverged at lam={}", f.lambda);
        assert_eq!(
            f.val_acc.to_bits(),
            i.val_acc.to_bits(),
            "val acc diverged at lam={}",
            f.lambda
        );
        assert_eq!(
            f.test_acc.to_bits(),
            i.test_acc.to_bits(),
            "test acc diverged at lam={}",
            f.lambda
        );
        // history equality covers the whole trajectory: warmup records
        // (carried from the shared phase), per-step losses (batch-order
        // sensitive) and eval records
        assert_history_eq(&f.history, &i.history);
    }
    let fp = forked.front();
    let ip = indep.front();
    assert_eq!(fp.len(), ip.len());
    for (a, b) in fp.points().iter().zip(ip.points()) {
        assert_eq!(a.cost.to_bits(), b.cost.to_bits());
        assert_eq!(a.acc.to_bits(), b.acc.to_bits());
    }
}

/// Parallel workers fork from the same snapshot concurrently and must
/// not perturb each other (or the shared `WarmStart`).
#[test]
fn forked_sweep_is_deterministic_across_worker_counts() {
    let fx = Fx::new("workers");
    let runner = fx.ctx.runner(fixture::STUB_MODEL).unwrap();
    let cfg = quick_cfg();
    let solo = sweep_lambdas(
        &runner,
        &cfg,
        &LAMBDAS,
        "size",
        &opts(SweepMode::ForkedWarmup, 1),
    )
    .unwrap();
    let pooled = sweep_lambdas(
        &runner,
        &cfg,
        &LAMBDAS,
        "size",
        &opts(SweepMode::ForkedWarmup, 3),
    )
    .unwrap();
    for (a, b) in solo.runs.iter().zip(&pooled.runs) {
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.val_acc.to_bits(), b.val_acc.to_bits());
        assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits());
    }
}

/// (b) Warmup executes exactly once per forked sweep; the savings show
/// up in both the step counters and the transfer stats.
#[test]
fn forked_sweep_runs_warmup_exactly_once() {
    let fx = Fx::new("once");
    let runner = fx.ctx.runner(fixture::STUB_MODEL).unwrap();
    let cfg = quick_cfg();
    let forked = sweep_lambdas(
        &runner,
        &cfg,
        &LAMBDAS,
        "size",
        &opts(SweepMode::ForkedWarmup, 1),
    )
    .unwrap();
    let indep = sweep_lambdas(
        &runner,
        &cfg,
        &LAMBDAS,
        "size",
        &opts(SweepMode::Independent, 1),
    )
    .unwrap();
    // step counters: one shared phase vs one phase per lambda
    assert_eq!(forked.warmup_steps_run, cfg.warmup_steps);
    assert_eq!(indep.warmup_steps_run, cfg.warmup_steps * LAMBDAS.len());
    assert_eq!(
        forked.warmup_steps_saved,
        cfg.warmup_steps * (LAMBDAS.len() - 1)
    );
    assert_eq!(indep.warmup_steps_saved, 0);
    // the shared phase did real work...
    assert!(forked.shared_warmup.h2d_bytes > 0);
    assert!(forked.shared_warmup_s >= 0.0);
    // ...and each forked run is exactly one warmup phase lighter
    for (f, i) in forked.runs.iter().zip(&indep.runs) {
        assert_eq!(f.steps_run + cfg.warmup_steps, i.steps_run);
        assert_eq!(f.timing.warmup_s, 0.0, "fork must not charge warmup time");
        assert!(
            f.transfer.h2d_bytes < i.transfer.h2d_bytes,
            "fork h2d {} not below independent h2d {}",
            f.transfer.h2d_bytes,
            i.transfer.h2d_bytes
        );
    }
    // whole-sweep traffic: shared warmup counted once must still beat
    // per-lambda warmups
    let forked_total: u64 = forked.shared_warmup.total_bytes()
        + forked.runs.iter().map(|r| r.transfer.total_bytes()).sum::<u64>();
    let indep_total: u64 =
        indep.runs.iter().map(|r| r.transfer.total_bytes()).sum::<u64>();
    assert!(
        forked_total < indep_total,
        "forked sweep moved {forked_total} B, independent {indep_total} B"
    );
}

fn stats_delta(after: TransferStats, before: TransferStats) -> (u64, u64) {
    (
        after.h2d_bytes - before.h2d_bytes,
        after.d2h_bytes - before.d2h_bytes,
    )
}

/// (c) Batched eval == per-batch eval bitwise, ragged chunk included,
/// with strictly fewer host<->device bytes; the split upload is cached
/// across calls.
#[test]
fn batched_eval_matches_per_batch_exactly() {
    let fx = Fx::new("eval");
    let mm = fx.ctx.man.model(fixture::STUB_MODEL).unwrap();
    let runner = fx.ctx.runner(fixture::STUB_MODEL).unwrap();
    let data_cfg = &fx.ctx.dataset(fixture::STUB_MODEL).cfg;
    // the fixture invariant this test relies on: a ragged final chunk
    assert_ne!(data_cfg.n_val % mm.batch, 0, "val split must be ragged");
    assert_ne!(data_cfg.n_test % mm.batch, 0, "test split must be ragged");

    let eval = StepFn::bind(&fx.ctx.eng, &fx.ctx.man, mm, "eval").unwrap();
    let eval_b = StepFn::bind(&fx.ctx.eng, &fx.ctx.man, mm, "eval_batched").unwrap();
    let mut state = DeviceState::init(&fx.ctx.eng, &fx.ctx.man, mm, 42).unwrap();
    let masks = MaskBufs::new(&fx.ctx.eng, &PrecisionMasks::joint()).unwrap();
    let mut bufs = EvalBufs::new();

    for (split, tau) in [(Split::Val, 0.8f32), (Split::Test, 0.3f32)] {
        let before = state.stats;
        let (l_pb, a_pb) = runner
            .evaluate(&eval, &mut state, split, &masks, tau, true, false)
            .unwrap();
        let (pb_h2d, pb_d2h) = stats_delta(state.stats, before);

        let before = state.stats;
        let (l_b, a_b) = runner
            .evaluate_batched(&eval_b, &mut state, split, &mut bufs, &masks, tau, true, false)
            .unwrap();
        let (b_h2d, b_d2h) = stats_delta(state.stats, before);

        assert_eq!(l_pb.to_bits(), l_b.to_bits(), "{split:?} loss diverged");
        assert_eq!(a_pb.to_bits(), a_b.to_bits(), "{split:?} acc diverged");
        // first batched call uploads the split once but skips the
        // per-chunk scalar re-uploads: strictly fewer bytes
        assert!(
            b_h2d + b_d2h < pb_h2d + pb_d2h,
            "{split:?}: batched {b_h2d}+{b_d2h} B not below per-batch {pb_h2d}+{pb_d2h} B"
        );

        // second batched call reuses the cached split: only the two
        // scalar knobs cross, metrics come back
        let before = state.stats;
        let (l_b2, a_b2) = runner
            .evaluate_batched(&eval_b, &mut state, split, &mut bufs, &masks, tau, true, false)
            .unwrap();
        let (c_h2d, _c_d2h) = stats_delta(state.stats, before);
        assert_eq!(l_b2.to_bits(), l_b.to_bits());
        assert_eq!(a_b2.to_bits(), a_b.to_bits());
        assert_eq!(c_h2d, 8, "cached eval should upload only tau + hard");
    }
}

/// Full pipelines with batched vs per-batch eval produce identical
/// results while the batched run moves strictly fewer bytes.
#[test]
fn pipeline_with_batched_eval_is_equivalent_and_cheaper() {
    let fx = Fx::new("pipeline");
    let runner = fx.ctx.runner(fixture::STUB_MODEL).unwrap();
    let cfg = quick_cfg();
    let mut cfg_pb = cfg.clone();
    cfg_pb.batched_eval = false;
    let batched = runner.run(&cfg).unwrap();
    let per_batch = runner.run(&cfg_pb).unwrap();
    assert_eq!(batched.assignment, per_batch.assignment);
    assert_eq!(batched.val_acc.to_bits(), per_batch.val_acc.to_bits());
    assert_eq!(batched.test_acc.to_bits(), per_batch.test_acc.to_bits());
    assert_history_eq(&batched.history, &per_batch.history);
    assert!(
        batched.transfer.total_bytes() < per_batch.transfer.total_bytes(),
        "batched {} B not below per-batch {} B",
        batched.transfer.total_bytes(),
        per_batch.transfer.total_bytes()
    );
}

/// `run_from` refuses a config whose warmup trajectory cannot match
/// the snapshot it is forking.
#[test]
fn run_from_rejects_mismatched_config() {
    let fx = Fx::new("guard");
    let runner = fx.ctx.runner(fixture::STUB_MODEL).unwrap();
    let cfg = quick_cfg();
    let ws = runner.warmup(&cfg).unwrap();
    let mut bad_seed = cfg.clone();
    bad_seed.seed += 1;
    assert!(runner.run_from(&ws, &bad_seed).is_err());
    let mut bad_warmup = cfg.clone();
    bad_warmup.warmup_steps += 1;
    assert!(runner.run_from(&ws, &bad_warmup).is_err());
    // the matching config forks fine (and more than once)
    assert!(runner.run_from(&ws, &cfg).is_ok());
    assert!(runner.run_from(&ws, &cfg).is_ok());
}
