//! Property-based tests of coordinator invariants (hand-rolled
//! harness in `util::prop`; the offline registry has no proptest).

use mixprec::assignment::{Assignment, PW_SET};
use mixprec::coordinator::{ParetoFront, Point};
use mixprec::cost::by_name;
use mixprec::deploy::{refine_for_ne16, reorder_assignment, split_layers};
use mixprec::graph::ModelGraph;
use mixprec::util::json::Json;
use mixprec::util::prop::{shrink_vec, Prop};
use mixprec::util::rng::Pcg64;

fn tiny_graph() -> ModelGraph {
    let text = r#"{
      "model": "tiny", "in_shape": [8,8,3], "num_classes": 4, "batch": 2,
      "layers": [
        {"name":"c0","kind":"conv","cin":3,"cout":16,"k":3,"stride":1,
         "out_h":8,"out_w":8,"gamma_group":0,"in_group":-1,
         "delta_idx":0,"in_delta":-1,"prunable":true,"macs":27648},
        {"name":"dw0","kind":"dw","cin":16,"cout":16,"k":3,"stride":1,
         "out_h":8,"out_w":8,"gamma_group":0,"in_group":0,
         "delta_idx":1,"in_delta":0,"prunable":true,"macs":9216},
        {"name":"c1","kind":"conv","cin":16,"cout":24,"k":3,"stride":2,
         "out_h":4,"out_w":4,"gamma_group":1,"in_group":0,
         "delta_idx":2,"in_delta":1,"prunable":true,"macs":55296},
        {"name":"fc","kind":"linear","cin":24,"cout":4,"k":1,"stride":1,
         "out_h":1,"out_w":1,"gamma_group":2,"in_group":1,
         "delta_idx":-1,"in_delta":2,"prunable":false,"macs":96}
      ],
      "gamma_groups": [16, 24, 4], "num_deltas": 3,
      "pw_set": [0,2,4,8], "px_set": [2,4,8]
    }"#;
    ModelGraph::from_json(&Json::parse(text).unwrap()).unwrap()
}

fn random_assignment(rng: &mut Pcg64, graph: &ModelGraph) -> Assignment {
    let gamma_bits = graph
        .gamma_groups
        .iter()
        .enumerate()
        .map(|(g, &n)| {
            (0..n)
                .map(|_| {
                    // last group (fc) never pruned
                    let opts: &[u32] = if graph.group_prunable(g) {
                        &PW_SET
                    } else {
                        &PW_SET[1..]
                    };
                    opts[rng.below(opts.len() as u64) as usize]
                })
                .collect()
        })
        .collect();
    let delta_bits = (0..graph.num_deltas)
        .map(|_| [2u32, 4, 8][rng.below(3) as usize])
        .collect();
    Assignment {
        gamma_bits,
        delta_bits,
    }
}

#[test]
fn pareto_front_no_point_dominates_another() {
    let graph = tiny_graph();
    let _ = &graph;
    Prop::new(100).check(
        "pareto mutual non-dominance",
        |rng| {
            (0..rng.below(30) + 1)
                .map(|i| (rng.next_f64() * 100.0, rng.next_f64(), i))
                .collect::<Vec<_>>()
        },
        shrink_vec,
        |pts| {
            let front = ParetoFront::from_points(
                pts.iter().map(|(c, a, i)| Point::new(*c, *a, format!("{i}"))),
            );
            for p in front.points() {
                for q in front.points() {
                    if p != q && p.dominates(q) {
                        return Err(format!("{p:?} dominates {q:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn pareto_front_contains_extremes() {
    Prop::new(100).check(
        "front contains min-cost and max-acc",
        |rng| {
            (0..rng.below(20) + 1)
                .map(|_| (rng.next_f64() * 100.0, rng.next_f64()))
                .collect::<Vec<_>>()
        },
        shrink_vec,
        |pts| {
            let front = ParetoFront::from_points(
                pts.iter().map(|(c, a)| Point::new(*c, *a, "")),
            );
            let max_acc = pts.iter().map(|p| p.1).fold(f64::MIN, f64::max);
            if front.best_acc().map(|p| p.acc) != Some(max_acc) {
                return Err("max accuracy point missing from front".into());
            }
            Ok(())
        },
    );
}

#[test]
fn insertion_order_does_not_change_front() {
    Prop::new(60).check(
        "front is order-independent",
        |rng| {
            (0..rng.below(15) + 2)
                .map(|_| ((rng.next_f64() * 10.0).round(), (rng.next_f64() * 10.0).round() / 10.0))
                .collect::<Vec<_>>()
        },
        shrink_vec,
        |pts| {
            let f1 = ParetoFront::from_points(pts.iter().map(|(c, a)| Point::new(*c, *a, "")));
            let mut rev = pts.clone();
            rev.reverse();
            let f2 = ParetoFront::from_points(rev.iter().map(|(c, a)| Point::new(*c, *a, "")));
            let key = |f: &ParetoFront| -> Vec<(u64, u64)> {
                f.points()
                    .iter()
                    .map(|p| (p.cost.to_bits(), p.acc.to_bits()))
                    .collect()
            };
            if key(&f1) != key(&f2) {
                return Err(format!("fronts differ: {:?} vs {:?}", f1.points(), f2.points()));
            }
            Ok(())
        },
    );
}

#[test]
fn reorder_is_a_permutation_of_kept_channels() {
    let graph = tiny_graph();
    Prop::new(100).check(
        "reorder permutation",
        |rng| random_assignment(rng, &graph),
        |_| vec![],
        |asg| {
            let plan = reorder_assignment(asg);
            for (g, perm) in plan.perms.iter().enumerate() {
                let kept: Vec<usize> = (0..asg.gamma_bits[g].len())
                    .filter(|&c| asg.gamma_bits[g][c] > 0)
                    .collect();
                let mut sorted = perm.clone();
                sorted.sort_unstable();
                if sorted != kept {
                    return Err(format!("group {g}: {perm:?} not a perm of {kept:?}"));
                }
                // bits must be non-increasing after reorder
                for w in plan.bits[g].windows(2) {
                    if w[0] < w[1] {
                        return Err(format!("group {g}: bits not sorted {:?}", plan.bits[g]));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn split_total_bits_equals_size_cost() {
    let graph = tiny_graph();
    let size = by_name("size").unwrap();
    Prop::new(100).check(
        "split == size model",
        |rng| random_assignment(rng, &graph),
        |_| vec![],
        |asg| {
            let plan = reorder_assignment(asg);
            let subs = split_layers(&graph, &plan);
            let total: u64 = subs.iter().map(|s| s.weight_bits).sum();
            let cost = size.cost(&graph, asg);
            if (total as f64 - cost).abs() > 1e-6 {
                return Err(format!("split {total} != size {cost}"));
            }
            Ok(())
        },
    );
}

#[test]
fn cost_models_monotone_under_single_channel_reduction() {
    let graph = tiny_graph();
    Prop::new(60).check(
        "reducing one channel's bits never increases cost (size/bitops)",
        |rng| {
            let asg = random_assignment(rng, &graph);
            let g = rng.below(graph.gamma_groups.len() as u64) as usize;
            let c = rng.below(graph.gamma_groups[g] as u64) as usize;
            (asg, g, c)
        },
        |_| vec![],
        |(asg, g, c)| {
            let bits = asg.gamma_bits[*g][*c];
            let lower = match bits {
                8 => 4,
                4 => 2,
                2 if graph.group_prunable(*g) => 0,
                _ => return Ok(()),
            };
            let mut reduced = asg.clone();
            reduced.gamma_bits[*g][*c] = lower;
            // NOTE: intentionally not NE16 — its 32-channel PE
            // granularity makes single-channel reductions non-monotone
            // (that step structure is the paper's Fig. 8 finding).
            for name in ["size", "bitops", "mpic"] {
                let m = by_name(name).unwrap();
                let (a, b) = (m.cost(&graph, asg), m.cost(&graph, &reduced));
                if b > a + 1e-9 {
                    return Err(format!("{name}: {bits}->{lower} raised cost {a} -> {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn ne16_refinement_never_hurts() {
    let graph = tiny_graph();
    let ne16 = by_name("ne16").unwrap();
    Prop::new(40).check(
        "refine_for_ne16 sound",
        |rng| random_assignment(rng, &graph),
        |_| vec![],
        |asg| {
            let mut refined = asg.clone();
            let (before, after, _) = refine_for_ne16(&graph, &mut refined);
            if after > before + 1e-9 {
                return Err(format!("cost up: {before} -> {after}"));
            }
            if (ne16.cost(&graph, &refined) - after).abs() > 1e-9 {
                return Err("reported cost mismatch".into());
            }
            for (g, group) in refined.gamma_bits.iter().enumerate() {
                for (c, &b) in group.iter().enumerate() {
                    let orig = asg.gamma_bits[g][c];
                    if b < orig {
                        return Err(format!("bit decreased g{g}c{c}: {orig}->{b}"));
                    }
                    if (orig == 0) != (b == 0) {
                        return Err("pruning status changed".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn checkpoint_roundtrip_random_states() {
    use mixprec::coordinator::checkpoint;
    use mixprec::runtime::TrainState;
    use mixprec::util::tensor::Tensor;
    Prop::new(20).check(
        "checkpoint roundtrip",
        |rng| {
            let n = rng.below(5) + 1;
            (0..n)
                .map(|i| {
                    let len = (rng.below(50) + 1) as usize;
                    let data: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
                    (format!("sec{i}"), len, data)
                })
                .collect::<Vec<_>>()
        },
        shrink_vec,
        |secs| {
            let mut st = TrainState::default();
            for (name, len, data) in secs {
                st.sections
                    .insert(name.clone(), vec![Tensor::f32(vec![*len], data.clone())]);
            }
            let path = std::env::temp_dir().join(format!(
                "mixprec_prop_{}.ckpt",
                std::process::id()
            ));
            checkpoint::save(&st, &path).map_err(|e| e.to_string())?;
            let back = checkpoint::load(&path).map_err(|e| e.to_string())?;
            std::fs::remove_file(&path).ok();
            if back.sections != st.sections {
                return Err("state mismatch".into());
            }
            Ok(())
        },
    );
}

// ---- ParetoFront: iso queries, duplicates, emptiness ---------------

fn random_points(rng: &mut Pcg64, max: u64) -> Vec<(f64, f64)> {
    (0..rng.below(max) + 1)
        // coarse grid so duplicates and ties actually occur
        .map(|_| {
            (
                (rng.next_f64() * 8.0).round(),
                (rng.next_f64() * 8.0).round() / 8.0,
            )
        })
        .collect()
}

#[test]
fn pareto_iso_queries_return_optimal_front_members() {
    Prop::new(120).check(
        "iso_accuracy / iso_cost optimal and on the front",
        |rng| {
            let pts = random_points(rng, 24);
            let target = rng.next_f64();
            let budget = rng.next_f64() * 8.0;
            (pts, target, budget)
        },
        |(pts, t, b)| shrink_vec(pts).into_iter().map(|p| (p, *t, *b)).collect(),
        |(pts, target, budget)| {
            let front =
                ParetoFront::from_points(pts.iter().map(|(c, a)| Point::new(*c, *a, "")));
            let is_member = |p: &Point| {
                front
                    .points()
                    .iter()
                    .any(|q| q.cost == p.cost && q.acc == p.acc)
            };
            match front.iso_accuracy(*target) {
                Some(p) => {
                    if !is_member(p) {
                        return Err("iso_accuracy returned a non-member".into());
                    }
                    if p.acc < *target {
                        return Err(format!("iso_accuracy below target: {} < {target}", p.acc));
                    }
                    // optimality vs the *input* set, not just the front
                    if pts.iter().any(|&(c, a)| a >= *target && c < p.cost) {
                        return Err("iso_accuracy not the cheapest qualifying point".into());
                    }
                }
                None => {
                    if pts.iter().any(|&(_, a)| a >= *target) {
                        return Err("iso_accuracy missed a qualifying point".into());
                    }
                }
            }
            match front.iso_cost(*budget) {
                Some(p) => {
                    if !is_member(p) {
                        return Err("iso_cost returned a non-member".into());
                    }
                    if p.cost > *budget {
                        return Err(format!("iso_cost above budget: {} > {budget}", p.cost));
                    }
                    if pts.iter().any(|&(c, a)| c <= *budget && a > p.acc) {
                        return Err("iso_cost not the most accurate qualifying point".into());
                    }
                }
                None => {
                    if pts.iter().any(|&(c, _)| c <= *budget) {
                        return Err("iso_cost missed a qualifying point".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn pareto_front_has_no_coordinate_duplicates() {
    Prop::new(120).check(
        "front is a set in (cost, acc)",
        |rng| {
            // duplicate-heavy input: draw, then replay a prefix
            let mut pts = random_points(rng, 16);
            let extra = rng.below(pts.len() as u64 + 1) as usize;
            let dup: Vec<_> = pts[..extra].to_vec();
            pts.extend(dup);
            pts
        },
        shrink_vec,
        |pts| {
            let front =
                ParetoFront::from_points(pts.iter().map(|(c, a)| Point::new(*c, *a, "")));
            for (i, p) in front.points().iter().enumerate() {
                for q in &front.points()[i + 1..] {
                    if p.cost == q.cost && p.acc == q.acc {
                        return Err(format!("duplicate on front: {p:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn pareto_front_insert_order_independent_under_shuffle() {
    Prop::new(80).check(
        "front identical under random permutation of inserts",
        |rng| {
            let pts = random_points(rng, 16);
            let mut shuffled = pts.clone();
            rng.shuffle(&mut shuffled);
            (pts, shuffled)
        },
        |_| vec![],
        |(pts, shuffled)| {
            let key = |f: &ParetoFront| -> Vec<(u64, u64)> {
                f.points()
                    .iter()
                    .map(|p| (p.cost.to_bits(), p.acc.to_bits()))
                    .collect()
            };
            let f1 = ParetoFront::from_points(pts.iter().map(|(c, a)| Point::new(*c, *a, "")));
            let f2 = ParetoFront::from_points(
                shuffled.iter().map(|(c, a)| Point::new(*c, *a, "")),
            );
            if key(&f1) != key(&f2) {
                return Err(format!("{:?} vs {:?}", f1.points(), f2.points()));
            }
            Ok(())
        },
    );
}

#[test]
fn pareto_front_edge_cases() {
    // empty front: every query is None and the front reports empty
    let empty = ParetoFront::new();
    assert!(empty.is_empty());
    assert_eq!(empty.len(), 0);
    assert!(empty.iso_accuracy(0.0).is_none());
    assert!(empty.iso_cost(f64::MAX).is_none());
    assert!(empty.best_acc().is_none());

    // exact duplicates: second insert is rejected, first tag survives
    let mut f = ParetoFront::new();
    assert!(f.insert(Point::new(1.0, 0.5, "first")).unwrap());
    assert!(!f.insert(Point::new(1.0, 0.5, "second")).unwrap());
    assert_eq!(f.len(), 1);
    assert_eq!(f.points()[0].tag, "first");

    // NaN coordinates error out instead of poisoning the dominance
    // order (they compare false with everything)
    assert!(f.insert(Point::new(f64::NAN, 0.5, "nan")).is_err());
    assert_eq!(f.len(), 1);

    // same cost, better accuracy still evicts
    assert!(f.insert(Point::new(1.0, 0.9, "better")).unwrap());
    assert_eq!(f.len(), 1);
    assert_eq!(f.points()[0].tag, "better");

    // a single point answers both iso queries
    assert_eq!(f.iso_accuracy(0.9).unwrap().tag, "better");
    assert!(f.iso_accuracy(0.91).is_none());
    assert_eq!(f.iso_cost(1.0).unwrap().tag, "better");
    assert!(f.iso_cost(0.99).is_none());
}
