//! Cross-language consistency: the exact Rust cost models must agree
//! with the differentiable Python regularizers. Pinned reference
//! values are shared with python/tests/test_regularizers.py
//! (TestCrossLanguagePins) — regenerate both if either side changes.

use mixprec::assignment::Assignment;
use mixprec::coordinator::Context;
use mixprec::cost::by_name;

fn graph() -> Option<mixprec::graph::ModelGraph> {
    let dir = Context::artifacts_dir();
    let p = dir.join("graph_resnet8.json");
    if !p.exists() {
        eprintln!("SKIP: graph_resnet8.json missing");
        return None;
    }
    Some(mixprec::graph::ModelGraph::load(&p).unwrap())
}

#[test]
fn pinned_w8a8_maxima_match_python() {
    let Some(g) = graph() else { return };
    let w8 = Assignment::uniform(&g, 8);
    assert_eq!(by_name("size").unwrap().cost(&g, &w8), 618880.0);
    assert_eq!(g.total_macs(), 3125888);
    assert_eq!(by_name("bitops").unwrap().cost(&g, &w8), 200056832.0);
    let ne16 = by_name("ne16").unwrap().cost(&g, &w8);
    assert!((ne16 - 18246.13888888889).abs() < 1e-6, "{ne16}");
    let mpic = by_name("mpic").unwrap().cost(&g, &w8);
    assert!((mpic - 1116388.5714285716).abs() < 1e-3, "{mpic}");
}

#[test]
fn normalized_w4_and_w2_fractions() {
    let Some(g) = graph() else { return };
    // size normalizes exactly to bits/8
    let size = by_name("size").unwrap();
    assert!((size.normalized(&g, &Assignment::uniform(&g, 4)) - 0.5).abs() < 1e-12);
    assert!((size.normalized(&g, &Assignment::uniform(&g, 2)) - 0.25).abs() < 1e-12);
    // mpic w2a8: all MACs at (px=8, pw=2) -> 2.8/3.4 of the w8a8 cycles
    let mpic = by_name("mpic").unwrap();
    let frac = mpic.normalized(&g, &Assignment::uniform(&g, 2));
    assert!((frac - 2.8 / 3.4).abs() < 1e-9, "{frac}");
}

#[test]
fn graph_matches_manifest_shapes() {
    let dir = Context::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let man = mixprec::runtime::Manifest::load(&dir).unwrap();
    for (name, mm) in &man.models {
        let g = mixprec::graph::ModelGraph::load(&dir.join(&mm.graph_file)).unwrap();
        g.validate().unwrap();
        assert_eq!(g.batch, mm.batch, "{name}");
        assert_eq!(g.num_classes, mm.num_classes, "{name}");
        assert_eq!(g.in_shape, mm.in_shape, "{name}");
        // each gamma group has a matching theta leaf of shape (n, 4)
        for (gid, &n) in g.gamma_groups.iter().enumerate() {
            let leaf = format!("theta['gamma'][{gid}]");
            let idx = mm
                .leaf_index("theta", &leaf)
                .unwrap_or_else(|| panic!("{name}: {leaf} missing"));
            let desc = &mm.section("theta").unwrap()[idx];
            assert_eq!(desc.shape, vec![n, 4], "{name} {leaf}");
        }
        // delta leaf shape (num_deltas, 3)
        let didx = mm.leaf_index("theta", "theta['delta']").unwrap();
        assert_eq!(
            mm.section("theta").unwrap()[didx].shape,
            vec![g.num_deltas, 3],
            "{name}"
        );
        // every layer has w and b parameter leaves
        for l in &g.layers {
            assert!(
                mm.leaf_index("params", &format!("params['{}']['w']", l.name))
                    .is_some(),
                "{name}: missing w for {}",
                l.name
            );
            assert!(
                mm.leaf_index("params", &format!("params['{}']['b']", l.name))
                    .is_some(),
                "{name}: missing b for {}",
                l.name
            );
        }
    }
}
