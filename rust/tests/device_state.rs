//! Host/device state equivalence tests for the device-resident
//! runtime. These run against the stub fixture (`runtime::fixture`),
//! whose artifacts are deterministic `// STUB:` programs the host
//! backend executes — so the whole marshalling + dirty-sync layer is
//! exercised for real without AOT artifacts or native XLA.

use std::path::PathBuf;

use mixprec::coordinator::checkpoint;
use mixprec::runtime::{
    fixture, DeviceState, Engine, Manifest, StepArg, StepFn, TrainState,
};

struct Fx {
    dir: PathBuf,
    man: Manifest,
    eng: Engine,
}

impl Fx {
    fn new(tag: &str) -> Fx {
        let dir = std::env::temp_dir().join(format!(
            "mixprec_devstate_{tag}_{}",
            std::process::id()
        ));
        let man = fixture::write_stub_fixture(&dir).expect("fixture");
        let eng = Engine::cpu().expect("engine");
        Fx { dir, man, eng }
    }

    fn search(&self) -> StepFn {
        let mm = self.man.model(fixture::STUB_MODEL).unwrap();
        StepFn::bind(&self.eng, &self.man, mm, "search").expect("bind search")
    }

    fn init_state(&self) -> TrainState {
        fixture::stub_train_state(self.man.model(fixture::STUB_MODEL).unwrap())
    }

    /// One step through the seed's full-literal-marshal path.
    fn step_legacy(&self, search: &StepFn, st: &mut TrainState, step: usize) -> Vec<f32> {
        let ex = fixture::stub_search_extras(step);
        let m = search.step(st, &ex).expect("legacy step");
        m.values.values().cloned().collect()
    }

    /// One step through the device-resident path (all-host extras).
    fn step_dev(&self, search: &StepFn, st: &mut DeviceState, step: usize) -> Vec<f32> {
        let ex = fixture::stub_search_extras(step);
        let args: Vec<StepArg> = ex.iter().map(StepArg::Host).collect();
        let m = search
            .step_device(&self.eng, st, &args)
            .expect("device step");
        m.values.values().cloned().collect()
    }
}

impl Drop for Fx {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

/// N search steps: device-resident state must stay bitwise identical
/// to both the seed full-marshal path (`StepFn::step`) and the forced
/// per-step-roundtrip compat mode, metrics included.
#[test]
fn device_path_matches_legacy_full_marshal_bitwise() {
    let fx = Fx::new("equiv");
    let search = fx.search();
    let mut legacy = fx.init_state();
    let mut dev = DeviceState::from_host(legacy.clone());
    let mut compat = DeviceState::from_host(legacy.clone());
    // device leg keeps the masks resident to cover StepArg::Device
    let ex0 = fixture::stub_search_extras(0);
    let pw = fx.eng.upload_tensor(&ex0[4]).unwrap();
    let px = fx.eng.upload_tensor(&ex0[5]).unwrap();
    for step in 0..7 {
        let ex = fixture::stub_search_extras(step);
        let m_legacy = fx.step_legacy(&search, &mut legacy, step);
        let m_dev = search
            .step_device(
                &fx.eng,
                &mut dev,
                &[
                    StepArg::Host(&ex[0]),
                    StepArg::Host(&ex[1]),
                    StepArg::Host(&ex[2]),
                    StepArg::Host(&ex[3]),
                    StepArg::Device(&pw),
                    StepArg::Device(&px),
                ],
            )
            .expect("device step")
            .values
            .values()
            .cloned()
            .collect::<Vec<f32>>();
        let m_compat = fx.step_dev(&search, &mut compat, step);
        compat.force_host_roundtrip().unwrap();
        assert_eq!(m_legacy, m_dev, "metrics diverged at step {step}");
        assert_eq!(m_legacy, m_compat, "compat metrics diverged at step {step}");
    }
    assert_eq!(
        dev.host_view().unwrap().sections,
        legacy.sections,
        "device-resident sections diverged from the legacy path"
    );
    assert_eq!(compat.host_view().unwrap().sections, legacy.sections);
}

/// Checkpoint round-trip through the sync layer: save a mid-training
/// device state, reload it, continue stepping — identical to never
/// having left the device, and to the legacy path.
#[test]
fn checkpoint_roundtrip_through_sync_layer() {
    let fx = Fx::new("ckpt");
    let search = fx.search();
    let mut legacy = fx.init_state();
    let mut dev = DeviceState::from_host(legacy.clone());
    for step in 0..3 {
        fx.step_legacy(&search, &mut legacy, step);
        fx.step_dev(&search, &mut dev, step);
    }
    let path = fx.dir.join("mid.ckpt");
    checkpoint::save_device(&mut dev, &path).unwrap();
    let mut reloaded = checkpoint::load_device(&path).unwrap();
    for step in 3..5 {
        fx.step_legacy(&search, &mut legacy, step);
        fx.step_dev(&search, &mut dev, step);
        fx.step_dev(&search, &mut reloaded, step);
    }
    let dev_host = dev.host_view().unwrap().sections.clone();
    assert_eq!(dev_host, legacy.sections);
    assert_eq!(reloaded.host_view().unwrap().sections, dev_host);
}

/// Host edits through `host_view_mut_partial` must reach the device
/// before the next step (dirty tracking), without touching the other
/// sections' residency.
#[test]
fn host_edits_are_uploaded_before_next_step() {
    let fx = Fx::new("dirty");
    let mm = fx.man.model(fixture::STUB_MODEL).unwrap();
    let search = fx.search();
    let mut legacy = fx.init_state();
    let mut dev = DeviceState::from_host(legacy.clone());
    for step in 0..2 {
        fx.step_legacy(&search, &mut legacy, step);
        fx.step_dev(&search, &mut dev, step);
    }
    let gamma = mm.leaf_id("theta", "theta['gamma'][0]").unwrap();
    for v in legacy.leaf_at_mut(&gamma).unwrap().as_f32_mut() {
        *v *= 2.0;
    }
    let d2h_before = dev.stats.d2h_bytes;
    {
        let host = dev.host_view_mut_partial(&["theta"]).unwrap();
        for v in host.leaf_at_mut(&gamma).unwrap().as_f32_mut() {
            *v *= 2.0;
        }
    }
    // partial sync downloaded only theta (3 small leaves, 83 floats:
    // gamma [16,4] + [4,4] + delta [1,3])
    assert_eq!(dev.stats.d2h_bytes - d2h_before, 83 * 4);
    for step in 2..4 {
        fx.step_legacy(&search, &mut legacy, step);
        fx.step_dev(&search, &mut dev, step);
    }
    assert_eq!(dev.host_view().unwrap().sections, legacy.sections);
}

/// Snapshots are cheap Arc handles but must restore the exact state.
#[test]
fn snapshot_restore_returns_exact_state() {
    let fx = Fx::new("snap");
    let search = fx.search();
    let mut dev = DeviceState::from_host(fx.init_state());
    for step in 0..2 {
        fx.step_dev(&search, &mut dev, step);
    }
    let snap = dev.snapshot(&fx.eng).unwrap();
    let saved = dev.to_host().unwrap();
    for step in 2..5 {
        fx.step_dev(&search, &mut dev, step);
    }
    assert_ne!(dev.host_view().unwrap().sections, saved.sections);
    dev.restore(&snap, Some(fx.eng.pool()));
    assert_eq!(dev.host_view().unwrap().sections, saved.sections);
}

/// The point of the tentpole: device residency moves orders of
/// magnitude fewer bytes per step than the forced full marshal.
#[test]
fn device_residency_slashes_transfer_bytes() {
    let fx = Fx::new("stats");
    let search = fx.search();
    let init = fx.init_state();
    let mut dev = DeviceState::from_host(init.clone());
    let mut compat = DeviceState::from_host(init);
    for step in 0..10 {
        fx.step_dev(&search, &mut dev, step);
        fx.step_dev(&search, &mut compat, step);
        compat.force_host_roundtrip().unwrap();
    }
    // both paths upload the same extras; the compat path re-marshals
    // the whole state (~34 KB each way) every step on top of that
    assert!(
        dev.stats.h2d_bytes * 5 < compat.stats.h2d_bytes,
        "device h2d {} vs compat h2d {}",
        dev.stats.h2d_bytes,
        compat.stats.h2d_bytes
    );
    assert!(
        dev.stats.d2h_bytes * 5 < compat.stats.d2h_bytes,
        "device d2h {} vs compat d2h {}",
        dev.stats.d2h_bytes,
        compat.stats.d2h_bytes
    );
    // the allocation side of the same story: every state leaf of every
    // step was donated in place (16 leaves x 10 steps), with no
    // fallback of either kind — nothing pins an unsnapshotted state
    assert_eq!(dev.alloc.donated, 16 * 10);
    assert_eq!(dev.alloc.fallback_pinned, 0);
    assert_eq!(dev.alloc.fallback_aliased, 0);
}

/// Device-resident extras get the same shape validation the legacy
/// host path applied: a swapped mask pair must error, not corrupt.
#[test]
fn swapped_device_masks_rejected() {
    let fx = Fx::new("maskswap");
    let search = fx.search();
    let mut dev = DeviceState::from_host(fx.init_state());
    let ex = fixture::stub_search_extras(0);
    let pw = fx.eng.upload_tensor(&ex[4]).unwrap();
    let px = fx.eng.upload_tensor(&ex[5]).unwrap();
    let r = search.step_device(
        &fx.eng,
        &mut dev,
        &[
            StepArg::Host(&ex[0]),
            StepArg::Host(&ex[1]),
            StepArg::Host(&ex[2]),
            StepArg::Host(&ex[3]),
            StepArg::Device(&px), // swapped
            StepArg::Device(&pw),
        ],
    );
    assert!(r.is_err(), "swapped device masks were accepted");
}

/// Contract checks: stale device sections must be synced before use;
/// unknown sections error.
#[test]
fn stale_and_missing_sections_error() {
    let fx = Fx::new("contract");
    let mut dev = DeviceState::from_host(fx.init_state());
    assert!(dev.device_bufs("params").is_err(), "stale section served");
    dev.sync_to_device(&fx.eng, &["params".to_string()]).unwrap();
    assert_eq!(dev.device_bufs("params").unwrap().len(), 5);
    assert!(dev.device_bufs("nope").is_err());
    assert!(dev.host_view_partial(&["params"]).is_ok());
}
