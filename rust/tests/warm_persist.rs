//! Cross-process warm-start persistence (`--warm-cache-dir`), on the
//! stub fixture. "Two processes" are emulated by two `Context`s — each
//! owns its own engine, `SharedRunCache` and device buffers, so
//! nothing but the shared directory can carry state between them.
//!
//! Contract under test (ISSUE 5 acceptance):
//! (a) process A persists its warmup; process B pointed at the same
//!     `--warm-cache-dir` runs **zero** warmup steps and produces a
//!     Pareto front (and per-run histories) bitwise identical to A's
//!     in-process warmup;
//! (b) a corrupted warm file falls back to a fresh warmup — never an
//!     error, never a wrong resume — and is rewritten;
//! (c) a fingerprint-mismatched file (foreign config, or a legacy v1
//!     checkpoint) is rejected structurally and falls back.

use std::path::PathBuf;

use mixprec::coordinator::{sweep_lambdas, Context, PipelineConfig, SweepMode, SweepOptions};
use mixprec::runtime::fixture;

struct Fx {
    dir: PathBuf,
    warm: PathBuf,
}

impl Fx {
    /// data_frac 0.07 -> ragged val/test splits, so the persisted
    /// state + iterator cover the padded-tail geometry too.
    fn new(tag: &str) -> Fx {
        let dir = std::env::temp_dir().join(format!(
            "mixprec_warmpersist_{tag}_{}",
            std::process::id()
        ));
        fixture::write_stub_fixture(&dir).expect("fixture");
        let warm = dir.join("warmcache");
        Fx { dir, warm }
    }

    /// A fresh "process": its own engine, cache and buffers, sharing
    /// only the artifacts directory and the warm-cache directory.
    fn process(&self) -> Context {
        let ctx = Context::load(&self.dir, 0.07).expect("context");
        ctx.shared_cache().set_warm_dir(Some(self.warm.clone()));
        ctx
    }
}

impl Drop for Fx {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

fn quick_cfg() -> PipelineConfig {
    let mut cfg = PipelineConfig::quick(fixture::STUB_MODEL);
    cfg.warmup_steps = 12;
    cfg.search_steps = 24;
    cfg.finetune_steps = 6;
    cfg.eval_every = 8;
    cfg.steps_per_epoch = 8;
    cfg
}

fn opts() -> SweepOptions {
    SweepOptions {
        workers: 1,
        mode: SweepMode::ForkedWarmup,
        vary_seeds: false,
        share_warmup: true,
    }
}

const LAMBDAS: [f64; 2] = [0.05, 5.0];

fn front_bits(sw: &mixprec::coordinator::SweepResult) -> Vec<(u64, u64)> {
    sw.front()
        .points()
        .iter()
        .map(|p| (p.cost.to_bits(), p.acc.to_bits()))
        .collect()
}

/// (a) Persist in process A, resume in process B: zero warmup steps,
/// bitwise-identical fronts, histories and accuracies.
#[test]
fn second_process_runs_zero_warmup_steps_with_identical_front() {
    let fx = Fx::new("resume");
    let cfg = quick_cfg();

    // process A: fresh warmup, persisted to the shared directory
    let ctx_a = fx.process();
    let runner_a = ctx_a.runner_shared(fixture::STUB_MODEL).unwrap();
    let sw_a = sweep_lambdas(&runner_a, &cfg, &LAMBDAS, "size", &opts()).unwrap();
    assert_eq!(sw_a.warmup_steps_run, cfg.warmup_steps);
    assert!(!sw_a.warmup_loaded);
    assert_eq!(sw_a.warmups_persisted, 1, "warmup must be persisted");
    let warm_file = ctx_a
        .shared_cache()
        .warm_file_path(&runner_a.warmup_cache_key(&cfg))
        .unwrap();
    assert!(warm_file.exists(), "no warm file at {warm_file:?}");

    // process B: same directory, fresh everything else
    let ctx_b = fx.process();
    let runner_b = ctx_b.runner_shared(fixture::STUB_MODEL).unwrap();
    let sw_b = sweep_lambdas(&runner_b, &cfg, &LAMBDAS, "size", &opts()).unwrap();
    assert_eq!(sw_b.warmup_steps_run, 0, "resume must run ZERO warmup steps");
    assert_eq!(sw_b.warmup_phases_run, 0);
    assert!(sw_b.warmup_loaded, "warmup must come from the disk tier");
    assert_eq!(sw_b.warmups_loaded, 1);
    assert_eq!(sw_b.warmups_persisted, 0, "nothing new to persist");
    assert_eq!(
        sw_b.warmup_steps_saved,
        cfg.warmup_steps * LAMBDAS.len(),
        "everything an independent sweep would have spent is saved"
    );
    let st_b = ctx_b.shared_cache().stats();
    assert_eq!((st_b.warmups_run, st_b.warmups_loaded), (0, 1));

    // bitwise equivalence: fronts, accuracies, full histories
    // (warmup records included — they ride in the warm file)
    assert_eq!(front_bits(&sw_a), front_bits(&sw_b), "front diverged");
    assert_eq!(sw_a.runs.len(), sw_b.runs.len());
    for (a, b) in sw_a.runs.iter().zip(&sw_b.runs) {
        assert_eq!(a.lambda, b.lambda);
        assert_eq!(a.assignment, b.assignment, "lam={}", a.lambda);
        assert_eq!(a.val_acc.to_bits(), b.val_acc.to_bits());
        assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits());
        assert_eq!(a.history.len(), b.history.len(), "history length diverged");
        for (x, y) in a.history.iter().zip(&b.history) {
            assert_eq!(x.phase, y.phase);
            assert_eq!(x.step, y.step);
            assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{}[{}]", x.phase, x.step);
            assert_eq!(x.acc.to_bits(), y.acc.to_bits(), "{}[{}]", x.phase, x.step);
            assert_eq!(x.cost.to_bits(), y.cost.to_bits(), "{}[{}]", x.phase, x.step);
        }
    }

    // a third "process" reuses the same entry (load path is stable)
    let ctx_c = fx.process();
    let runner_c = ctx_c.runner_shared(fixture::STUB_MODEL).unwrap();
    let sw_c = sweep_lambdas(&runner_c, &cfg, &LAMBDAS, "size", &opts()).unwrap();
    assert_eq!(sw_c.warmup_steps_run, 0);
    assert_eq!(front_bits(&sw_a), front_bits(&sw_c));
}

/// (b) A corrupted (or truncated/torn) warm file degrades to a fresh
/// warmup without error, produces the same results, and is rewritten.
#[test]
fn corrupted_warm_file_falls_back_to_fresh_warmup() {
    let fx = Fx::new("corrupt");
    let cfg = quick_cfg();

    let ctx_a = fx.process();
    let runner_a = ctx_a.runner_shared(fixture::STUB_MODEL).unwrap();
    let sw_a = sweep_lambdas(&runner_a, &cfg, &LAMBDAS, "size", &opts()).unwrap();
    let warm_file = ctx_a
        .shared_cache()
        .warm_file_path(&runner_a.warmup_cache_key(&cfg))
        .unwrap();

    for garbage in [&b"complete garbage"[..], &b""[..]] {
        std::fs::write(&warm_file, garbage).unwrap();
        let ctx_b = fx.process();
        let runner_b = ctx_b.runner_shared(fixture::STUB_MODEL).unwrap();
        let sw_b = sweep_lambdas(&runner_b, &cfg, &LAMBDAS, "size", &opts()).unwrap();
        assert_eq!(
            sw_b.warmup_steps_run, cfg.warmup_steps,
            "corrupt entry must mean a fresh warmup"
        );
        assert!(!sw_b.warmup_loaded);
        assert_eq!(sw_b.warmups_loaded, 0);
        assert_eq!(sw_b.warmups_persisted, 1, "fresh warmup rewrites the entry");
        assert_eq!(front_bits(&sw_a), front_bits(&sw_b), "fallback diverged");
    }

    // a truncated-but-valid-prefix file (torn write simulation — the
    // atomic rename makes this unobservable in practice, but the
    // decoder must still reject it)
    let full = std::fs::read(&warm_file).unwrap();
    std::fs::write(&warm_file, &full[..full.len() / 2]).unwrap();
    let ctx_b = fx.process();
    let runner_b = ctx_b.runner_shared(fixture::STUB_MODEL).unwrap();
    let sw_b = sweep_lambdas(&runner_b, &cfg, &LAMBDAS, "size", &opts()).unwrap();
    assert_eq!(sw_b.warmup_steps_run, cfg.warmup_steps);
    assert_eq!(front_bits(&sw_a), front_bits(&sw_b));
}

/// (c) A structurally mismatched entry — a foreign config's warm file
/// placed at this key's path, or a legacy v1 checkpoint — is rejected
/// by the stored fingerprint and falls back to a fresh warmup.
#[test]
fn mismatched_fingerprint_falls_back_to_fresh_warmup() {
    let fx = Fx::new("mismatch");
    let cfg = quick_cfg();

    // persist under cfg...
    let ctx_a = fx.process();
    let runner_a = ctx_a.runner_shared(fixture::STUB_MODEL).unwrap();
    sweep_lambdas(&runner_a, &cfg, &LAMBDAS, "size", &opts()).unwrap();
    let file_a = ctx_a
        .shared_cache()
        .warm_file_path(&runner_a.warmup_cache_key(&cfg))
        .unwrap();

    // ...then plant A's file at the path a *different* config resolves
    // (simulating a filename/hash collision across fingerprints)
    let mut other = cfg.clone();
    other.warmup_steps += 4;
    let file_other = ctx_a
        .shared_cache()
        .warm_file_path(&runner_a.warmup_cache_key(&other))
        .unwrap();
    assert_ne!(file_a, file_other, "distinct fingerprints, distinct files");
    std::fs::copy(&file_a, &file_other).unwrap();

    let ctx_b = fx.process();
    let runner_b = ctx_b.runner_shared(fixture::STUB_MODEL).unwrap();
    let sw = sweep_lambdas(&runner_b, &other, &LAMBDAS, "size", &opts()).unwrap();
    assert_eq!(
        sw.warmup_steps_run, other.warmup_steps,
        "foreign fingerprint must not seed a resume"
    );
    assert!(!sw.warmup_loaded);

    // a legacy v1 checkpoint at the expected path: loads as a state
    // with no extras -> decode declines -> fresh warmup, no error
    let mut st = mixprec::runtime::TrainState::default();
    st.sections.insert(
        "params".into(),
        vec![mixprec::util::tensor::Tensor::scalar_f32(1.0)],
    );
    mixprec::coordinator::checkpoint::save_v1(&st, &file_a).unwrap();
    let ctx_c = fx.process();
    let runner_c = ctx_c.runner_shared(fixture::STUB_MODEL).unwrap();
    let sw = sweep_lambdas(&runner_c, &cfg, &LAMBDAS, "size", &opts()).unwrap();
    assert_eq!(sw.warmup_steps_run, cfg.warmup_steps);
    assert!(!sw.warmup_loaded);
    assert_eq!(sw.warmups_persisted, 1, "entry rewritten in v2 form");
}
