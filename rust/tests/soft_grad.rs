//! Finite-difference validation of the differentiable cost surface
//! (the `CostModel::soft_eval` contract every regularizer driver
//! relies on), via the hand-rolled `util::prop` harness:
//!
//! * every registry model's `soft_grad` matches a central finite
//!   difference of its `soft_cost` at random interior points — the
//!   analytic surfaces are per-coordinate polynomials of degree <= 2,
//!   and the interpolated fallback is affine between argmax flips, so
//!   the central difference is exact wherever the hardened argmax is
//!   stable (near-tie rows are skipped);
//! * zoo-wide sign/ordering invariants: within every gamma row the
//!   gradient is smallest at the pruned column and nondecreasing along
//!   the precision set, and every delta row is nondecreasing along the
//!   activation set — "lowering precision or pruning never raises the
//!   soft cost", the monotonicity the lambda sweep's cost axis needs;
//! * the analytic builtin four additionally keep every gradient entry
//!   nonnegative (their adjoints only accumulate nonnegative terms);
//! * `size`/`bitops`/`mpic` and the fallback models agree with the
//!   discrete `cost` at one-hot vertices (`ne16` deliberately does
//!   not: its `div_ceil` tiling is relaxed to linear ramps).

use mixprec::assignment::Assignment;
use mixprec::cost::{CostModel, CostRegistry, Roofline, SoftAssignment};
use mixprec::graph::ModelGraph;
use mixprec::util::json::Json;
use mixprec::util::prop::Prop;
use mixprec::util::rng::Pcg64;

fn tiny_graph() -> ModelGraph {
    let text = r#"{
      "model": "tiny", "in_shape": [8,8,3], "num_classes": 4, "batch": 2,
      "layers": [
        {"name":"c0","kind":"conv","cin":3,"cout":8,"k":3,"stride":1,
         "out_h":8,"out_w":8,"gamma_group":0,"in_group":-1,
         "delta_idx":0,"in_delta":-1,"prunable":true,"macs":13824},
        {"name":"dw0","kind":"dw","cin":8,"cout":8,"k":3,"stride":1,
         "out_h":8,"out_w":8,"gamma_group":0,"in_group":0,
         "delta_idx":1,"in_delta":0,"prunable":true,"macs":4608},
        {"name":"fc","kind":"linear","cin":8,"cout":4,"k":1,"stride":1,
         "out_h":1,"out_w":1,"gamma_group":1,"in_group":0,
         "delta_idx":-1,"in_delta":1,"prunable":false,"macs":32}
      ],
      "gamma_groups": [8, 4], "num_deltas": 2,
      "pw_set": [0,2,4,8], "px_set": [2,4,8]
    }"#;
    ModelGraph::from_json(&Json::parse(text).unwrap()).unwrap()
}

/// The full surface under test: the committed zoo plus one
/// descriptor-registered roofline, so plugged-in models go through the
/// same contract as the builtins.
fn registry() -> CostRegistry {
    let mut reg = CostRegistry::zoo();
    let desc = Json::parse(
        r#"{"type":"roofline","name":"plug-soc",
            "peak_macs_per_s":1.0e9,"dram_bytes_per_s":1.0e8}"#,
    )
    .unwrap();
    reg.register_descriptor(&desc).unwrap();
    reg
}

fn softmax(logits: &[f64]) -> Vec<f64> {
    let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let e: Vec<f64> = logits.iter().map(|&x| (x - m).exp()).collect();
    let s: f64 = e.iter().sum();
    e.iter().map(|&x| x / s).collect()
}

/// Random interior point: independent softmax rows (4-wide per
/// channel, 3-wide per delta) from logits in [-2, 2].
fn random_soft(rng: &mut Pcg64, graph: &ModelGraph) -> SoftAssignment {
    let logit = |rng: &mut Pcg64| rng.below(4001) as f64 / 1000.0 - 2.0;
    let gamma = graph
        .gamma_groups
        .iter()
        .map(|&n| {
            let mut rows = Vec::with_capacity(n * 4);
            for _ in 0..n {
                let l = [logit(rng), logit(rng), logit(rng), logit(rng)];
                rows.extend(softmax(&l));
            }
            rows
        })
        .collect();
    let mut delta = Vec::with_capacity(graph.num_deltas * 3);
    for _ in 0..graph.num_deltas {
        let l = [logit(rng), logit(rng), logit(rng)];
        delta.extend(softmax(&l));
    }
    SoftAssignment { gamma, delta }
}

/// Top-2 margin of one probability row: perturbing a coordinate of a
/// near-tie row can flip the interpolated fallback's hardened argmax,
/// making the surface only piecewise — those rows are skipped.
fn row_margin(row: &[f64]) -> f64 {
    let mut a = f64::NEG_INFINITY;
    let mut b = f64::NEG_INFINITY;
    for &p in row {
        if p > a {
            b = a;
            a = p;
        } else if p > b {
            b = p;
        }
    }
    a - b
}

const FD_H: f64 = 1e-5;
const MARGIN: f64 = 1e-3;

/// Central finite difference of `soft_cost` along one flat coordinate
/// (`gamma_group = Some(g)` or the delta block).
fn central_fd(
    model: &dyn CostModel,
    graph: &ModelGraph,
    soft: &SoftAssignment,
    gamma_group: Option<usize>,
    idx: usize,
) -> f64 {
    let mut lo = soft.clone();
    let mut hi = soft.clone();
    match gamma_group {
        Some(g) => {
            lo.gamma[g][idx] -= FD_H;
            hi.gamma[g][idx] += FD_H;
        }
        None => {
            lo.delta[idx] -= FD_H;
            hi.delta[idx] += FD_H;
        }
    }
    (model.soft_cost(graph, &hi) - model.soft_cost(graph, &lo)) / (2.0 * FD_H)
}

#[test]
fn soft_grad_matches_central_differences() {
    let g = tiny_graph();
    let reg = registry();
    Prop::new(24).check(
        "soft_grad == central FD for every registered model",
        |rng| random_soft(rng, &g),
        |_| Vec::new(),
        |soft| {
            for m in reg.iter() {
                let (cost, grad) = m.soft_eval(&g, soft);
                if !cost.is_finite() {
                    return Err(format!("{}: non-finite soft cost {cost}", m.name()));
                }
                let tol = 1e-9 * m.max_cost(&g).max(1.0);
                for (gi, rows) in grad.gamma.iter().enumerate() {
                    for (j, &an) in rows.iter().enumerate() {
                        let row = &soft.gamma[gi][(j / 4) * 4..(j / 4) * 4 + 4];
                        if row_margin(row) < MARGIN {
                            continue;
                        }
                        let fd = central_fd(m.as_ref(), &g, soft, Some(gi), j);
                        if (fd - an).abs() > tol {
                            return Err(format!(
                                "{}: gamma[{gi}][{j}] analytic {an} vs FD {fd} (tol {tol})",
                                m.name()
                            ));
                        }
                    }
                }
                for (j, &an) in grad.delta.iter().enumerate() {
                    let row = &soft.delta[(j / 3) * 3..(j / 3) * 3 + 3];
                    if row_margin(row) < MARGIN {
                        continue;
                    }
                    let fd = central_fd(m.as_ref(), &g, soft, None, j);
                    if (fd - an).abs() > tol {
                        return Err(format!(
                            "{}: delta[{j}] analytic {an} vs FD {fd} (tol {tol})",
                            m.name()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn zoo_gradients_respect_cost_monotonicity() {
    let g = tiny_graph();
    let reg = registry();
    let analytic = ["size", "bitops", "mpic", "ne16"];
    Prop::new(32).check(
        "gamma rows nondecreasing along PW, delta rows along PX, prune column minimal",
        |rng| random_soft(rng, &g),
        |_| Vec::new(),
        |soft| {
            for m in reg.iter() {
                let grad = m.soft_grad(&g, soft);
                let tol = 1e-9 * m.max_cost(&g).max(1.0);
                for (gi, rows) in grad.gamma.iter().enumerate() {
                    for c in 0..rows.len() / 4 {
                        let r = &rows[c * 4..c * 4 + 4];
                        // pruning a channel is never costlier than
                        // keeping it at any precision...
                        for (j, &v) in r.iter().enumerate().skip(1) {
                            if r[0] > v + tol {
                                return Err(format!(
                                    "{}: gamma[{gi}] ch {c}: prune grad {} > col {j} grad {v}",
                                    m.name(),
                                    r[0]
                                ));
                            }
                        }
                        // ...and more weight bits never cost less
                        for j in 1..3 {
                            if r[j] > r[j + 1] + tol {
                                return Err(format!(
                                    "{}: gamma[{gi}] ch {c}: grad not monotone \
                                     along PW: {:?}",
                                    m.name(),
                                    r
                                ));
                            }
                        }
                        if analytic.contains(&m.name()) && r.iter().any(|&v| v < -tol) {
                            return Err(format!(
                                "{}: negative analytic gamma grad {r:?}",
                                m.name()
                            ));
                        }
                    }
                }
                for d in 0..grad.delta.len() / 3 {
                    let r = &grad.delta[d * 3..d * 3 + 3];
                    for j in 0..2 {
                        if r[j] > r[j + 1] + tol {
                            return Err(format!(
                                "{}: delta {d}: grad not monotone along PX: {r:?}",
                                m.name()
                            ));
                        }
                    }
                    if analytic.contains(&m.name()) && r.iter().any(|&v| v < -tol) {
                        return Err(format!(
                            "{}: negative analytic delta grad {r:?}",
                            m.name()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Vertex consistency across random *hard* assignments: at one-hot
/// points the soft surface of every model except `ne16` reproduces the
/// discrete cost exactly (the interpolated fallback by construction,
/// the analytic `size`/`bitops`/`mpic` because their relaxations are
/// multilinear).
#[test]
fn soft_cost_agrees_with_hard_cost_at_random_vertices() {
    let g = tiny_graph();
    let reg = registry();
    Prop::new(48).check(
        "soft == hard at one-hot vertices (zoo minus ne16)",
        |rng| {
            let gamma_bits = g
                .gamma_groups
                .iter()
                .enumerate()
                .map(|(gi, &n)| {
                    let opts: &[u32] =
                        if g.group_prunable(gi) { &[0, 2, 4, 8] } else { &[2, 4, 8] };
                    (0..n).map(|_| opts[rng.below(opts.len() as u64) as usize]).collect()
                })
                .collect();
            let delta_bits = (0..g.num_deltas)
                .map(|_| [2u32, 4, 8][rng.below(3) as usize])
                .collect();
            Assignment { gamma_bits, delta_bits }
        },
        |_| Vec::new(),
        |asg| {
            let soft = SoftAssignment::from_hard(&g, asg);
            for m in reg.iter().filter(|m| m.name() != "ne16") {
                let hard = m.cost(&g, asg);
                let s = m.soft_cost(&g, &soft);
                let tol = 1e-9 * m.max_cost(&g).max(1.0);
                if (s - hard).abs() > tol {
                    return Err(format!(
                        "{}: soft {s} != hard {hard} at a vertex",
                        m.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The descriptor-registered model (default `soft_eval`) and a builtin
/// with an analytic override expose the same fingerprint semantics:
/// same content -> same hash, different content -> different hash.
#[test]
fn descriptor_fingerprints_track_content() {
    let a = Roofline::new("soc", 1.0e9, 1.0e8);
    let b = Roofline::new("soc", 1.0e9, 1.0e8);
    let c = Roofline::new("soc", 2.0e9, 1.0e8);
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_ne!(a.fingerprint(), c.fingerprint());
}
