//! Donation-fallback safety tests for the allocation-free step
//! engine. The contract under test: `step_device` donates every
//! consumed-and-replaced state leaf (in-place update when exclusively
//! owned), falls back to a copy whenever a snapshot or fork pins the
//! leaf, recycles dead buffers through the engine's pool — and through
//! all of it stays **bitwise identical** to the copying legacy path,
//! with pinned payloads provably untouched.

use std::path::PathBuf;
use std::sync::Arc;

use mixprec::runtime::{
    fixture, DeviceState, Engine, Manifest, StateSnapshot, StepArg, StepFn, TrainState,
};
use mixprec::util::prop::Prop;

/// Leaves per donatable step of the fixture's `search` artifact
/// (params 5 + opt_w 5 + theta 3 + opt_th 3).
const LEAVES: u64 = 16;
/// Scalar metrics per `search` step.
const METRICS: u64 = 3;

struct Fx {
    dir: PathBuf,
    man: Manifest,
    eng: Engine,
}

impl Fx {
    fn new(tag: &str) -> Fx {
        let dir = std::env::temp_dir().join(format!(
            "mixprec_donation_{tag}_{}",
            std::process::id()
        ));
        let man = fixture::write_stub_fixture(&dir).expect("fixture");
        let eng = Engine::cpu().expect("engine");
        Fx { dir, man, eng }
    }

    fn search(&self) -> StepFn {
        let mm = self.man.model(fixture::STUB_MODEL).unwrap();
        StepFn::bind(&self.eng, &self.man, mm, "search").expect("bind search")
    }

    fn init_state(&self) -> TrainState {
        fixture::stub_train_state(self.man.model(fixture::STUB_MODEL).unwrap())
    }

    fn step_legacy(&self, search: &StepFn, st: &mut TrainState, step: usize) -> Vec<f32> {
        let ex = fixture::stub_search_extras(step);
        let m = search.step(st, &ex).expect("legacy step");
        m.values.values().cloned().collect()
    }

    fn step_dev(&self, search: &StepFn, st: &mut DeviceState, step: usize) -> Vec<f32> {
        let ex = fixture::stub_search_extras(step);
        let args: Vec<StepArg> = ex.iter().map(StepArg::Host).collect();
        let m = search
            .step_device(&self.eng, st, &args)
            .expect("device step");
        m.values.values().cloned().collect()
    }
}

impl Drop for Fx {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

/// Unpinned stepping donates every leaf every step, pools every metric
/// buffer after the first step, and stays bitwise identical to the
/// legacy full-marshal path.
#[test]
fn donated_steps_match_legacy_bitwise_and_are_alloc_free() {
    let fx = Fx::new("steady");
    let search = fx.search();
    let mut legacy = fx.init_state();
    let mut dev = DeviceState::from_host(legacy.clone());
    const N: usize = 9;
    for step in 0..N {
        let m_legacy = fx.step_legacy(&search, &mut legacy, step);
        let m_dev = fx.step_dev(&search, &mut dev, step);
        assert_eq!(m_legacy, m_dev, "metrics diverged at step {step}");
    }
    assert_eq!(
        dev.host_view().unwrap().sections,
        legacy.sections,
        "donated trajectory diverged from the copying path"
    );
    let al = dev.alloc;
    assert_eq!(al.donated, LEAVES * N as u64, "every leaf donates every step");
    assert_eq!(al.fallback_pinned, 0, "nothing pins an unsnapshotted state");
    assert_eq!(al.fallback_aliased, 0, "buffer-level aliasing must never occur");
    assert_eq!(al.allocated, METRICS, "only the first step's metrics allocate");
    assert_eq!(al.pooled, METRICS * (N as u64 - 1), "metrics recycle thereafter");
}

/// A snapshot pins every leaf: the next step must fall back to copies
/// (counted as pinned), the pinned payloads must restore bitwise
/// intact afterwards, and the trajectory must still match legacy.
#[test]
fn snapshot_survives_donated_stepping_bitwise() {
    let fx = Fx::new("snapshot");
    let search = fx.search();
    let mut legacy = fx.init_state();
    let mut dev = DeviceState::from_host(legacy.clone());
    for step in 0..2 {
        fx.step_legacy(&search, &mut legacy, step);
        fx.step_dev(&search, &mut dev, step);
    }
    let snap = dev.snapshot(&fx.eng).unwrap();
    let saved = dev.to_host().unwrap();
    let pinned_before = dev.alloc.fallback_pinned;
    for step in 2..7 {
        fx.step_legacy(&search, &mut legacy, step);
        fx.step_dev(&search, &mut dev, step);
    }
    // only the first post-snapshot step found the leaves pinned; the
    // step's own outputs are exclusively owned again
    assert_eq!(dev.alloc.fallback_pinned - pinned_before, LEAVES);
    assert_eq!(dev.alloc.fallback_aliased, 0);
    // the copy-fallback path is bitwise identical too
    assert_eq!(dev.host_view().unwrap().sections, legacy.sections);
    assert_ne!(dev.host_view().unwrap().sections, saved.sections);
    // N donated steps later, the pinned snapshot is untouched
    dev.restore(&snap, Some(fx.eng.pool()));
    assert_eq!(
        dev.host_view().unwrap().sections,
        saved.sections,
        "donation mutated a snapshot-pinned payload"
    );
}

/// Forked-warmup shape: two states forked off one snapshot step in
/// lockstep. First steps fall back (the snapshot + sibling pin every
/// leaf), later steps donate, trajectories stay identical, and the
/// shared snapshot stays intact throughout.
#[test]
fn forks_share_snapshot_then_donate_independently() {
    let fx = Fx::new("forks");
    let search = fx.search();
    let mut dev = DeviceState::from_host(fx.init_state());
    for step in 0..2 {
        fx.step_dev(&search, &mut dev, step);
    }
    let snap = dev.snapshot(&fx.eng).unwrap();
    let base = dev.to_host().unwrap();
    let mut f1 = DeviceState::from_snapshot(&snap);
    let mut f2 = DeviceState::from_snapshot(&snap);
    for step in 2..6 {
        let m1 = fx.step_dev(&search, &mut f1, step);
        let m2 = fx.step_dev(&search, &mut f2, step);
        assert_eq!(m1, m2, "fork metrics diverged at step {step}");
    }
    assert_eq!(f1.host_view().unwrap().sections, f2.host_view().unwrap().sections);
    for f in [&f1, &f2] {
        assert_eq!(f.alloc.fallback_pinned, LEAVES, "one pinned first step per fork");
        assert_eq!(f.alloc.fallback_aliased, 0);
        assert_eq!(f.alloc.donated, LEAVES * 3, "later fork steps donate");
    }
    // the shared snapshot restores the exact pre-fork state
    let mut check = DeviceState::from_snapshot(&snap);
    assert_eq!(check.host_view().unwrap().sections, base.sections);
}

/// The pool-side refcount rule, end to end on runtime types: a buffer
/// with a live clone is refused, the sole owner is accepted.
#[test]
fn pool_refuses_live_buffers_and_recycles_dead_ones() {
    let eng = Engine::cpu().unwrap();
    let pool = Arc::clone(eng.pool());
    let before = pool.stats();
    let buf = eng.upload(&xla::Literal::vec1(&[1f32, 2.0, 3.0])).unwrap();
    // buffer-level clone keeps the payload alive: retire must refuse
    let alias = (*buf).clone();
    assert!(!pool.retire(alias), "pool accepted an aliased payload");
    assert_eq!(pool.stats().refused - before.refused, 1);
    // last handle: accepted, then served back out
    let owned = Arc::try_unwrap(buf).ok().expect("sole outer handle");
    assert!(pool.retire(owned));
    assert_eq!(pool.stats().retired - before.retired, 1);
}

/// Property: across randomized interleavings of step / snapshot /
/// restore / host-roundtrip, the donated+pooled engine stays bitwise
/// identical to the legacy host path, the last snapshot is never
/// corrupted, and no aliased fallback ever fires. If a pool-recycled
/// buffer could alias a live `Arc`, one of these comparisons would
/// diverge.
#[test]
fn prop_random_interleavings_never_corrupt_snapshots() {
    let fx = Fx::new("prop");
    let search = fx.search();
    Prop::new(24).check(
        "donation interleaving",
        |rng| {
            let n = 4 + (rng.next_u64() % 9) as usize;
            (0..n).map(|_| (rng.next_u64() % 4) as u8).collect::<Vec<u8>>()
        },
        |ops: &Vec<u8>| {
            // shrink by dropping any single op
            (0..ops.len())
                .map(|i| {
                    let mut v = ops.clone();
                    v.remove(i);
                    v
                })
                .collect()
        },
        |ops| {
            let mut legacy = fx.init_state();
            let mut dev = DeviceState::from_host(legacy.clone());
            let mut snap: Option<(StateSnapshot, TrainState)> = None;
            let mut step = 0usize;
            for (i, op) in ops.iter().enumerate() {
                match *op {
                    0 => {
                        let ml = fx.step_legacy(&search, &mut legacy, step);
                        let md = fx.step_dev(&search, &mut dev, step);
                        if ml != md {
                            return Err(format!("metrics diverged at op {i} (step {step})"));
                        }
                        step += 1;
                    }
                    1 => {
                        let s = dev
                            .snapshot(&fx.eng)
                            .map_err(|e| format!("snapshot: {e}"))?;
                        snap = Some((s, legacy.clone()));
                    }
                    2 => {
                        if let Some((s, host)) = &snap {
                            dev.restore(s, Some(fx.eng.pool()));
                            legacy = host.clone();
                        }
                    }
                    _ => {
                        dev.force_host_roundtrip()
                            .map_err(|e| format!("roundtrip: {e}"))?;
                    }
                }
            }
            let dev_host = dev
                .host_view()
                .map_err(|e| format!("host_view: {e}"))?
                .sections
                .clone();
            if dev_host != legacy.sections {
                return Err("device trajectory diverged from legacy".into());
            }
            if let Some((s, host)) = &snap {
                let mut check = DeviceState::from_snapshot(s);
                let snap_host = check
                    .host_view()
                    .map_err(|e| format!("snapshot view: {e}"))?;
                if snap_host.sections != host.sections {
                    return Err("live snapshot corrupted by donation/pooling".into());
                }
            }
            if dev.alloc.fallback_aliased != 0 {
                return Err(format!(
                    "aliased donation fallback fired: {:?}",
                    dev.alloc
                ));
            }
            Ok(())
        },
    );
}
