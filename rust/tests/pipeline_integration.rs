//! Integration tests over the real AOT artifacts: init/step/eval
//! round-trips, mask-driven baselines, discretization semantics and
//! Eq. 12 rescaling — the L3 <-> L2 contract. Skipped (pass
//! trivially) when `make artifacts` has not been run.

use mixprec::assignment::{self, PrecisionMasks, ResolvedLeaves};
use mixprec::coordinator::{Context, PipelineConfig, Sampling};
use mixprec::data::Split;
use mixprec::runtime::{StepFn, TrainState};
use mixprec::util::tensor::Tensor;

fn ctx() -> Option<Context> {
    let dir = Context::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    Some(Context::load(&dir, 0.05).expect("context"))
}

fn search_extras(
    data: &mixprec::data::DataSet,
    batch: usize,
    masks: &PrecisionMasks,
    lam: f32,
    lr_th: f32,
    t: f32,
) -> Vec<Tensor> {
    let idx: Vec<usize> = (0..batch).collect();
    let (x, y) = data.batch(Split::Train, &idx, batch);
    vec![
        x,
        y,
        Tensor::scalar_f32(1e-3),
        Tensor::scalar_f32(lr_th),
        Tensor::scalar_f32(1.0),
        Tensor::scalar_f32(lam),
        Tensor::scalar_f32(0.0),
        Tensor::scalar_f32(0.0),
        Tensor::scalar_i32(7),
        Tensor::scalar_f32(t),
        masks.pw_tensor(),
        masks.px_tensor(),
    ]
}

#[test]
fn init_is_deterministic_in_seed() {
    let Some(ctx) = ctx() else { return };
    let mm = ctx.man.model("resnet8").unwrap();
    let a = TrainState::init(&ctx.eng, &ctx.man, mm, 5).unwrap();
    let b = TrainState::init(&ctx.eng, &ctx.man, mm, 5).unwrap();
    let c = TrainState::init(&ctx.eng, &ctx.man, mm, 6).unwrap();
    assert_eq!(a.sections, b.sections);
    assert_ne!(a.sections, c.sections);
    // all four sections present with manifest-matching leaf counts
    for sec in ["params", "opt_w", "theta", "opt_th"] {
        assert_eq!(
            a.section(sec).unwrap().len(),
            mm.section(sec).unwrap().len()
        );
    }
}

#[test]
fn theta_init_matches_eq13() {
    let Some(ctx) = ctx() else { return };
    let mm = ctx.man.model("resnet8").unwrap();
    let st = TrainState::init(&ctx.eng, &ctx.man, mm, 0).unwrap();
    let g0 = st.leaf(mm, "theta", "theta['gamma'][0]").unwrap();
    // every row is [0, .25, .5, 1] (Eq. 13 with P_W = {0,2,4,8})
    for row in g0.as_f32().chunks(4) {
        assert_eq!(row, &[0.0, 0.25, 0.5, 1.0]);
    }
}

#[test]
fn warmup_steps_reduce_loss() {
    let Some(ctx) = ctx() else { return };
    let model = "dscnn";
    let mm = ctx.man.model(model).unwrap();
    let data = ctx.dataset(model);
    let mut st = TrainState::init(&ctx.eng, &ctx.man, mm, 1).unwrap();
    let warm = StepFn::bind(&ctx.eng, &ctx.man, mm, "warmup").unwrap();
    let idx: Vec<usize> = (0..mm.batch).collect();
    let (x, y) = data.batch(Split::Train, &idx, mm.batch);
    let mut losses = Vec::new();
    for t in 1..=40 {
        let m = warm
            .step(
                &mut st,
                &[
                    x.clone(),
                    y.clone(),
                    Tensor::scalar_f32(1e-2),
                    Tensor::scalar_f32(t as f32),
                ],
            )
            .unwrap();
        losses.push(m.get("loss"));
    }
    assert!(
        *losses.last().unwrap() < losses[0] * 0.8,
        "no learning: {:?}",
        &losses[..5]
    );
}

#[test]
fn fixed_mask_pins_assignment_and_cost() {
    let Some(ctx) = ctx() else { return };
    let model = "resnet8";
    let mm = ctx.man.model(model).unwrap();
    let graph = ctx.graph(model);
    let data = ctx.dataset(model);
    let masks = PrecisionMasks::fixed(4).unwrap();
    let mut st = TrainState::init(&ctx.eng, &ctx.man, mm, 2).unwrap();
    let search = StepFn::bind(&ctx.eng, &ctx.man, mm, "search_size").unwrap();
    for t in 1..=3 {
        let m = search
            .step(&mut st, &search_extras(data, mm.batch, &masks, 1.0, 1e-2, t as f32))
            .unwrap();
        assert!(m.get("loss").is_finite());
    }
    let leaves = ResolvedLeaves::new(mm, graph).unwrap();
    let asg = assignment::discretize(&st, &leaves, graph, &masks).unwrap();
    for group in &asg.gamma_bits {
        assert!(group.iter().all(|&b| b == 4), "{group:?}");
    }
    // exact cost agrees with the in-graph normalized cost (w4 = 0.5 of w8)
    let size = mixprec::cost::Size;
    use mixprec::cost::CostModel;
    let norm = size.normalized(graph, &asg);
    assert!((norm - 0.5).abs() < 1e-9, "{norm}");
}

#[test]
fn mixprec_mask_never_prunes_and_final_layer_protected() {
    let Some(ctx) = ctx() else { return };
    let model = "resnet8";
    let mm = ctx.man.model(model).unwrap();
    let graph = ctx.graph(model);
    let data = ctx.dataset(model);
    let masks = PrecisionMasks::mixprec();
    let mut st = TrainState::init(&ctx.eng, &ctx.man, mm, 3).unwrap();
    let search = StepFn::bind(&ctx.eng, &ctx.man, mm, "search_size").unwrap();
    for t in 1..=4 {
        search
            .step(&mut st, &search_extras(data, mm.batch, &masks, 8.0, 5e-2, t as f32))
            .unwrap();
    }
    let leaves = ResolvedLeaves::new(mm, graph).unwrap();
    let asg = assignment::discretize(&st, &leaves, graph, &masks).unwrap();
    for (g, group) in asg.gamma_bits.iter().enumerate() {
        assert!(group.iter().all(|&b| b > 0), "group {g} pruned: {group:?}");
    }
    // joint masks + high strength CAN prune, but never the fc group
    let joint = PrecisionMasks::joint();
    let asg2 = assignment::discretize(&st, &leaves, graph, &joint).unwrap();
    let fc = graph.layer("fc").unwrap();
    assert!(asg2.gamma_bits[fc.gamma_group].iter().all(|&b| b > 0));
}

#[test]
fn eval_metrics_match_search_eval_path() {
    let Some(ctx) = ctx() else { return };
    let model = "resnet8";
    let mm = ctx.man.model(model).unwrap();
    let data = ctx.dataset(model);
    let masks = PrecisionMasks::joint();
    let mut st = TrainState::init(&ctx.eng, &ctx.man, mm, 4).unwrap();
    let eval = StepFn::bind(&ctx.eng, &ctx.man, mm, "eval").unwrap();
    let idx: Vec<usize> = (0..mm.batch).collect();
    let (x, y) = data.batch(Split::Val, &idx, mm.batch);
    let run = |st: &mut TrainState| {
        eval.step(
            st,
            &[
                x.clone(),
                y.clone(),
                Tensor::scalar_f32(1.0),
                Tensor::scalar_f32(1.0),
                masks.pw_tensor(),
                masks.px_tensor(),
            ],
        )
        .unwrap()
    };
    let a = run(&mut st);
    let b = run(&mut st);
    assert_eq!(a.get("loss"), b.get("loss"));
    assert_eq!(a.get("acc"), b.get("acc"));
    assert!(a.get("cost") > 0.0 && a.get("cost") <= 1.01);
}

#[test]
fn rescale_weights_divides_by_keep_probability() {
    let Some(ctx) = ctx() else { return };
    let model = "resnet8";
    let mm = ctx.man.model(model).unwrap();
    let graph = ctx.graph(model);
    let masks = PrecisionMasks::joint();
    let mut st = TrainState::init(&ctx.eng, &ctx.man, mm, 5).unwrap();
    let before = st
        .leaf(mm, "params", "params['stem']['w']")
        .unwrap()
        .as_f32()
        .to_vec();
    let leaves = ResolvedLeaves::new(mm, graph).unwrap();
    assignment::rescale_weights(&mut st, &leaves, graph, &masks, 1.0).unwrap();
    let after = st
        .leaf(mm, "params", "params['stem']['w']")
        .unwrap()
        .as_f32()
        .to_vec();
    // Eq. 13 init: logits [0,.25,.5,1] with tau=1 -> keep prob is the
    // same for every channel; ratio must be uniform and > 1.
    let ratio = after[0] / before[0];
    assert!(ratio > 1.0 && ratio < 1.4, "{ratio}");
    for (a, b) in after.iter().zip(&before) {
        if b.abs() > 1e-6 {
            assert!((a / b - ratio).abs() < 1e-4);
        }
    }
}

#[test]
fn full_micro_pipeline_runs_all_samplings() {
    let Some(ctx) = ctx() else { return };
    let runner = ctx.runner("dscnn").unwrap();
    for sampling in [Sampling::Softmax, Sampling::Argmax, Sampling::Gumbel] {
        let mut cfg = PipelineConfig::quick("dscnn");
        cfg.warmup_steps = 6;
        cfg.search_steps = 6;
        cfg.finetune_steps = 3;
        cfg.eval_every = 3;
        cfg.sampling = sampling;
        cfg.data_frac = 0.05;
        let r = runner.run(&cfg).expect("pipeline");
        assert!(r.val_acc >= 0.0 && r.val_acc <= 1.0);
        assert!(r.size_kb > 0.0);
        assert_eq!(
            r.assignment.gamma_bits.len(),
            ctx.graph("dscnn").gamma_groups.len()
        );
    }
}
