//! Bitwise-equivalence tests for the vectorized / multi-threaded /
//! fused execution core of the `xla` host backend.
//!
//! The contract under test: for any stub program, any argument shapes
//! (including empty leaves and ragged eval tails), any mix of
//! donation / pin / borrow intents, and any thread count, the chunked
//! parallel fused path produces outputs and `ExecStats` **bitwise
//! identical** to the retained scalar reference path
//! (`ExecOptions::reference`), and repeated runs on a multi-thread
//! pool are identical to each other.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use mixprec::util::prop::Prop;
use mixprec::util::rng::Pcg64;
use xla::{ExecOptions, PjRtLoadedExecutable};

/// Thread counts every case is checked at (the configured count is
/// appended so the CI `MIXPREC_XLA_THREADS={1,4}` legs also exercise
/// the persistent global pool, not just scoped teams).
fn thread_counts() -> Vec<usize> {
    let mut ts = vec![1, 2, 8];
    ts.push(xla::configured_threads());
    ts
}

/// Write a one-line `// STUB:` program and compile it through the
/// public artifact path (text file -> proto -> computation -> exe).
fn compile(directive: &str) -> PjRtLoadedExecutable {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    let name = format!("mixprec_xla_exec_{}", std::process::id());
    let dir: PathBuf = std::env::temp_dir().join(name);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("p{}.hlo.txt", NEXT.fetch_add(1, Ordering::Relaxed)));
    std::fs::write(&path, format!("{directive}\n")).unwrap();
    let proto = xla::HloModuleProto::from_text_file(&path).unwrap();
    let client = xla::PjRtClient::cpu().unwrap();
    client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap()
}

/// One output leaf as raw bits (f32 compared by `to_bits`, never `==`,
/// so -0.0 vs 0.0 or NaN payload drift cannot slip through).
fn bits(lit: &xla::Literal) -> Vec<u32> {
    match lit.to_vec::<f32>() {
        Ok(v) => v.iter().map(|x| x.to_bits()).collect(),
        Err(_) => lit.to_vec::<i32>().unwrap().iter().map(|&x| x as u32).collect(),
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Intent {
    /// Borrowed: the executable must copy, never mutate.
    Borrow,
    /// Donated and exclusively owned: updated in place.
    DonateOwned,
    /// Donated but aliased by a live clone: silent fallback copy.
    DonatePinned,
}

/// One property case: an `affine` program plus its argument plan.
/// Data is regenerated from `seed` per run, so the reference and every
/// threaded variant see byte-identical inputs and alias states.
#[derive(Debug, Clone)]
struct AffineCase {
    /// (element count, intent, i32 leaf) per state leaf.
    leaves: Vec<(usize, Intent, bool)>,
    /// Element counts of trailing broadcast (metric-only) args.
    extras: Vec<usize>,
    n_metrics: usize,
    seed: u64,
}

fn gen_affine(rng: &mut Pcg64) -> AffineCase {
    const LENS: [usize; 7] = [0, 1, 7, 8, 9, 33, 257];
    let leaves = (0..rng.below(6))
        .map(|_| {
            let len = LENS[rng.below(LENS.len() as u64) as usize];
            let intent = match rng.below(3) {
                0 => Intent::Borrow,
                1 => Intent::DonateOwned,
                _ => Intent::DonatePinned,
            };
            (len, intent, rng.below(4) == 0)
        })
        .collect();
    let extras = (0..rng.below(3)).map(|_| 1 + rng.below(8) as usize).collect();
    AffineCase {
        leaves,
        extras,
        n_metrics: rng.below(4) as usize,
        seed: rng.next_u64(),
    }
}

fn shrink_affine(c: &AffineCase) -> Vec<AffineCase> {
    let mut out = Vec::new();
    for i in 0..c.leaves.len() {
        let mut s = c.clone();
        s.leaves.remove(i);
        out.push(s);
    }
    for i in 0..c.extras.len() {
        let mut s = c.clone();
        s.extras.remove(i);
        out.push(s);
    }
    if c.n_metrics > 0 {
        let mut s = c.clone();
        s.n_metrics -= 1;
        out.push(s);
    }
    out
}

/// Build the case's arguments fresh and execute once. Returns every
/// output leaf's bits plus the backend's allocation counters.
fn run_affine(
    exe: &PjRtLoadedExecutable,
    case: &AffineCase,
    opts: &ExecOptions,
) -> Result<(Vec<Vec<u32>>, xla::ExecStats), String> {
    let mut rng = Pcg64::new(case.seed);
    let client = xla::PjRtClient::cpu().unwrap();
    let mut pins = Vec::new(); // clones that defeat donation
    let mut args = Vec::new();
    for &(len, intent, is_i32) in &case.leaves {
        let lit = if is_i32 {
            let v: Vec<i32> = (0..len).map(|_| rng.below(200) as i32 - 100).collect();
            xla::Literal::vec1(&v)
        } else {
            let v: Vec<f32> = (0..len).map(|_| rng.range_f32(-2.0, 2.0)).collect();
            xla::Literal::vec1(&v)
        };
        let buf = client.buffer_from_host_literal(&lit).unwrap();
        match intent {
            Intent::Borrow => args.push(xla::ExecInput::borrow(&buf)),
            Intent::DonateOwned => args.push(xla::ExecInput::donate(buf)),
            Intent::DonatePinned => {
                pins.push(buf.clone());
                args.push(xla::ExecInput::donate(buf));
            }
        }
    }
    for &len in &case.extras {
        let v: Vec<f32> = (0..len).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        args.push(xla::ExecInput::borrow(&xla::Literal::vec1(&v)));
    }
    let pool = xla::BufferPool::new();
    let (outs, stats) = exe.execute_d_opts(args, &pool, opts).map_err(|e| e.to_string())?;
    let res = outs[0]
        .iter()
        .map(|b| bits(&b.to_literal_sync().unwrap()))
        .collect();
    drop(pins);
    Ok((res, stats))
}

/// The reference options: scalar kernels, strictly sequential.
fn reference() -> ExecOptions {
    ExecOptions {
        threads: 1,
        reference: true,
        force_parallel: false,
    }
}

/// Chunked + threaded + fused, forced through the parallel path even
/// for sub-threshold programs.
fn vectorized(threads: usize) -> ExecOptions {
    ExecOptions {
        threads,
        reference: false,
        force_parallel: true,
    }
}

/// Property: the vectorized/threaded/fused affine path is bitwise
/// identical to the scalar reference — outputs *and* ExecStats — for
/// every leaf count, leaf length (incl. empty), element type, and
/// donation/pin/borrow mix, at every tested thread count.
#[test]
fn affine_threaded_matches_scalar_reference_bitwise() {
    Prop::new(40).check(
        "affine vectorized == scalar reference",
        gen_affine,
        shrink_affine,
        |case| {
            let exe = compile(&format!(
                "// STUB: affine scale=0.999 bias=0.0005 state={} metrics={}",
                case.leaves.len(),
                case.n_metrics
            ));
            let (want, want_stats) = run_affine(&exe, case, &reference())?;
            for t in thread_counts() {
                let (got, got_stats) = run_affine(&exe, case, &vectorized(t))?;
                if got != want {
                    return Err(format!("outputs diverged at {t} threads"));
                }
                if got_stats != want_stats {
                    return Err(format!(
                        "ExecStats diverged at {t} threads: {got_stats:?} vs {want_stats:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// One `evalchunks` property case. `ragged` appends a partial tail
/// chunk, which the program must reject identically on every path.
#[derive(Debug, Clone)]
struct EvalCase {
    batch: usize,
    chunks: usize,
    feat: usize,
    /// Broadcast args before x (x_arg = lead).
    lead: usize,
    /// Broadcast args after y.
    trail: usize,
    ragged: bool,
    seed: u64,
}

fn gen_eval(rng: &mut Pcg64) -> EvalCase {
    EvalCase {
        batch: 1 + rng.below(5) as usize,
        chunks: 1 + rng.below(6) as usize,
        feat: 1 + rng.below(4) as usize,
        lead: rng.below(3) as usize,
        trail: rng.below(2) as usize,
        ragged: rng.below(5) == 0,
        seed: rng.next_u64(),
    }
}

fn shrink_eval(c: &EvalCase) -> Vec<EvalCase> {
    let mut out = Vec::new();
    for (i, v) in [c.batch, c.chunks, c.feat].into_iter().enumerate() {
        if v > 1 {
            let mut s = c.clone();
            match i {
                0 => s.batch -= 1,
                1 => s.chunks -= 1,
                _ => s.feat -= 1,
            }
            out.push(s);
        }
    }
    for (i, v) in [c.lead, c.trail].into_iter().enumerate() {
        if v > 0 {
            let mut s = c.clone();
            match i {
                0 => s.lead -= 1,
                _ => s.trail -= 1,
            }
            out.push(s);
        }
    }
    if c.ragged {
        let mut s = c.clone();
        s.ragged = false;
        out.push(s);
    }
    out
}

fn run_eval(
    exe: &PjRtLoadedExecutable,
    case: &EvalCase,
    opts: &ExecOptions,
) -> Result<Vec<Vec<u32>>, String> {
    let mut rng = Pcg64::new(case.seed);
    let rows = case.batch * case.chunks + usize::from(case.ragged);
    let mut args = Vec::new();
    for _ in 0..case.lead {
        let v: Vec<f32> = (0..3).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        args.push(xla::ExecInput::borrow(&xla::Literal::vec1(&v)));
    }
    let x: Vec<f32> = (0..rows * case.feat).map(|_| rng.range_f32(-2.0, 2.0)).collect();
    let x = xla::Literal::vec1(&x)
        .reshape(&[rows as i64, case.feat as i64])
        .unwrap();
    args.push(xla::ExecInput::borrow(&x));
    let y: Vec<i32> = (0..rows).map(|_| rng.below(10) as i32).collect();
    args.push(xla::ExecInput::borrow(&xla::Literal::vec1(&y)));
    for _ in 0..case.trail {
        let v: Vec<f32> = (0..2).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        args.push(xla::ExecInput::borrow(&xla::Literal::vec1(&v)));
    }
    let pool = xla::BufferPool::new();
    let (outs, _) = exe.execute_d_opts(args, &pool, opts).map_err(|e| e.to_string())?;
    Ok(outs[0]
        .iter()
        .map(|b| bits(&b.to_literal_sync().unwrap()))
        .collect())
}

/// Property: chunk-parallel `evalchunks` scoring is bitwise identical
/// to the scalar reference, and ragged tails fail identically (same
/// error, state untouched) on every path.
#[test]
fn evalchunks_threaded_matches_scalar_reference_bitwise() {
    Prop::new(32).check(
        "evalchunks vectorized == scalar reference",
        gen_eval,
        shrink_eval,
        |case| {
            let exe = compile(&format!(
                "// STUB: evalchunks batch={} x={} metrics=2",
                case.batch, case.lead
            ));
            let want = run_eval(&exe, case, &reference());
            for t in thread_counts() {
                let got = run_eval(&exe, case, &vectorized(t));
                match (&want, &got) {
                    (Ok(w), Ok(g)) if w == g => {}
                    (Err(w), Err(g)) if w == g => {}
                    _ => return Err(format!("paths diverged at {t} threads: {want:?} vs {got:?}")),
                }
            }
            // one extra row is only actually ragged when batch > 1
            if case.ragged && case.batch > 1 && want.is_ok() {
                return Err("ragged tail must be rejected".into());
            }
            Ok(())
        },
    );
}

/// Running the same program three times on a multi-thread pool, with a
/// leaf set big enough to clear the parallelism threshold on its own,
/// yields bit-identical outputs every time — and identical to the
/// scalar reference.
#[test]
fn multithreaded_execution_is_deterministic_across_runs() {
    let exe = compile("// STUB: affine scale=0.999 bias=0.0005 state=8 metrics=3");
    let case = AffineCase {
        // 8 leaves x 8192 elems = 64K elements: above PAR_MIN_ELEMS
        // without force_parallel, so the default path also threads
        leaves: vec![(8192, Intent::Borrow, false); 8],
        extras: vec![4, 1],
        n_metrics: 3,
        seed: 0xd5ee_d001,
    };
    let (want, want_stats) = run_affine(&exe, &case, &reference()).unwrap();
    for run in 0..3 {
        let (got, got_stats) = run_affine(&exe, &case, &vectorized(8)).unwrap();
        assert_eq!(got, want, "run {run} diverged from the scalar reference");
        assert_eq!(got_stats, want_stats, "run {run} counters diverged");
    }
    // the default options (no force_parallel) take the threaded path
    // for this size and must also be identical
    let (got, got_stats) = run_affine(&exe, &case, &ExecOptions::default()).unwrap();
    assert_eq!(got, want);
    assert_eq!(got_stats, want_stats);
}

/// The thread-count knob resolves to something sane everywhere the
/// runtime reports it.
#[test]
fn configured_threads_is_positive() {
    assert!(xla::configured_threads() >= 1);
}
