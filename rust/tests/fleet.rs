//! Fleet crash matrix (ISSUE 9 acceptance): under every injected
//! failure — worker kill (stale lease), torn lease, torn result, torn
//! warm checkpoint, double-claim race, persistent mid-run faults —
//! the merged front and histories stay bitwise identical to the
//! single-process run, no unit is lost, and no result merges twice.
//!
//! "Workers" are emulated the `warm_persist.rs` way: each participant
//! is its own `Context` (own engine, `SharedRunCache`, buffers), so
//! only the shared job directory carries state between them.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use mixprec::baselines::{compare_methods, COMPARE_METHODS};
use mixprec::coordinator::fleet::{
    enumerate_job, lease_path, quar_path, read_quarantine, ready_path, result_path, write_lease,
    Lease,
};
use mixprec::coordinator::{
    compare_methods_fleet, run_worker, sweep_lambdas, sweep_lambdas_fleet, Context, FaultPlan,
    FleetOptions, PipelineConfig, RunResult, SweepMode, SweepOptions, SweepResult,
};
use mixprec::runtime::fixture;

struct Fx {
    dir: PathBuf,
}

impl Fx {
    /// data_frac 0.07 -> ragged val/test splits, so the shared warm
    /// checkpoint + iterator cover the padded-tail geometry too.
    fn new(tag: &str) -> Fx {
        let dir = std::env::temp_dir().join(format!(
            "mixprec_fleet_{tag}_{}",
            std::process::id()
        ));
        fixture::write_stub_fixture(&dir).expect("fixture");
        Fx { dir }
    }

    /// A fresh "process": own engine, cache and buffers. No warm dir
    /// is attached here — the fleet entry points attach the job
    /// directory themselves.
    fn process(&self) -> Context {
        Context::load(&self.dir, 0.07).expect("context")
    }

    /// A fresh shared job directory under the fixture root.
    fn fleet_dir(&self, tag: &str) -> PathBuf {
        let d = self.dir.join(format!("fleet_{tag}"));
        std::fs::create_dir_all(&d).unwrap();
        d
    }
}

impl Drop for Fx {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

fn quick_cfg() -> PipelineConfig {
    let mut cfg = PipelineConfig::quick(fixture::STUB_MODEL);
    cfg.warmup_steps = 12;
    cfg.search_steps = 24;
    cfg.finetune_steps = 6;
    cfg.eval_every = 8;
    cfg.steps_per_epoch = 8;
    cfg
}

fn opts() -> SweepOptions {
    SweepOptions {
        workers: 1,
        mode: SweepMode::ForkedWarmup,
        vary_seeds: false,
        share_warmup: true,
    }
}

/// Tight knobs so the crash matrix turns over in milliseconds; the
/// 30 s TTL keeps live leases from expiring under a slow test host
/// (the stale-lease tests plant `ttl_secs: 0` leases instead).
fn fleet_opts(dir: &Path, owner: &str) -> FleetOptions {
    FleetOptions {
        dir: dir.to_path_buf(),
        owner: owner.to_string(),
        ttl: Duration::from_secs(30),
        max_attempts: 3,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(8),
        poll: Duration::from_millis(10),
        ready_wait: Duration::from_secs(60),
        workers_external: 0,
        faults: Arc::new(FaultPlan::none()),
    }
}

const LAMBDAS: [f64; 2] = [0.05, 5.0];
const LAMBDAS4: [f64; 4] = [0.05, 0.5, 1.5, 5.0];

fn front_bits(sw: &SweepResult) -> Vec<(u64, u64)> {
    sw.front()
        .points()
        .iter()
        .map(|p| (p.cost.to_bits(), p.acc.to_bits()))
        .collect()
}

/// Bitwise equality of the deterministic run payload: lambda,
/// assignment, accuracies and the full per-step history (timing and
/// transfer counters are wall-clock/process-local and excluded).
fn assert_same_runs(a: &[RunResult], b: &[RunResult]) {
    assert_eq!(a.len(), b.len(), "run count diverged");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.lambda.to_bits(), y.lambda.to_bits());
        assert_eq!(x.assignment, y.assignment, "lam={}", x.lambda);
        assert_eq!(x.val_acc.to_bits(), y.val_acc.to_bits(), "lam={}", x.lambda);
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "lam={}", x.lambda);
        assert_eq!(x.history.len(), y.history.len(), "history length diverged");
        for (p, q) in x.history.iter().zip(&y.history) {
            assert_eq!((p.phase, p.step), (q.phase, q.step));
            assert_eq!(p.loss.to_bits(), q.loss.to_bits(), "{}[{}]", p.phase, p.step);
            assert_eq!(p.acc.to_bits(), q.acc.to_bits(), "{}[{}]", p.phase, p.step);
            assert_eq!(p.cost.to_bits(), q.cost.to_bits(), "{}[{}]", p.phase, p.step);
        }
    }
}

/// Failure-free fleet sweep: bitwise identity plus exact protocol
/// accounting (every unit claimed once, no lease files left behind).
#[test]
fn fleet_sweep_is_bitwise_identical_to_single_process() {
    let fx = Fx::new("ident");
    let cfg = quick_cfg();

    let ctx_ref = fx.process();
    let runner_ref = ctx_ref.runner_shared(fixture::STUB_MODEL).unwrap();
    let sw_ref = sweep_lambdas(&runner_ref, &cfg, &LAMBDAS, "size", &opts()).unwrap();

    let dir = fx.fleet_dir("ident");
    let ctx = fx.process();
    let runner = ctx.runner_shared(fixture::STUB_MODEL).unwrap();
    let (sw, fs) = sweep_lambdas_fleet(
        &runner,
        &cfg,
        &LAMBDAS,
        "size",
        &opts(),
        &fleet_opts(&dir, "coord"),
    )
    .unwrap();

    assert_eq!(front_bits(&sw_ref), front_bits(&sw), "front diverged");
    assert_same_runs(&sw_ref.runs, &sw.runs);
    assert_eq!(sw.warmup_steps_run, cfg.warmup_steps, "coordinator warms up once");
    assert_eq!(sw.warmups_persisted, 1, "warm checkpoint published for workers");
    let n = LAMBDAS.len() as u64;
    assert_eq!((fs.units, fs.completed, fs.leases_claimed), (n, n, n));
    assert_eq!(
        (fs.leases_expired, fs.leases_stolen, fs.retries, fs.quarantined),
        (0, 0, 0, 0)
    );

    // protocol hygiene: ready marker + results persist, leases do not
    let job = enumerate_job(&runner, &cfg, &LAMBDAS, "size", false);
    assert!(ready_path(&dir, job.fp).exists(), "ready marker missing");
    for u in &job.units {
        assert!(result_path(&dir, u.id).exists(), "result file missing");
        assert!(!lease_path(&dir, u.id).exists(), "lease left behind");
    }
}

/// Failure-free fleet compare: per-method fronts, histories and the
/// fixed baselines all bitwise identical; warm accounting matches the
/// single-process "1 built, 3 reused" trace.
#[test]
fn fleet_compare_is_bitwise_identical_to_single_process() {
    let fx = Fx::new("compare");
    let cfg = quick_cfg();

    let ctx_ref = fx.process();
    let runner_ref = ctx_ref.runner_shared(fixture::STUB_MODEL).unwrap();
    let cr_ref = compare_methods(&runner_ref, &cfg, &LAMBDAS, "size", &opts(), &[2, 8]).unwrap();

    let dir = fx.fleet_dir("compare");
    let ctx = fx.process();
    let runner = ctx.runner_shared(fixture::STUB_MODEL).unwrap();
    let (cr, fs) = compare_methods_fleet(
        &runner,
        &cfg,
        &LAMBDAS,
        "size",
        &opts(),
        &[2, 8],
        &fleet_opts(&dir, "coord"),
    )
    .unwrap();

    let units = (COMPARE_METHODS.len() * LAMBDAS.len()) as u64;
    assert_eq!((fs.units, fs.completed, fs.leases_claimed), (units, units, units));
    assert_eq!((fs.retries, fs.quarantined), (0, 0));

    assert_eq!(cr_ref.sweeps.len(), cr.sweeps.len());
    for ((ma, sa), (mb, sb)) in cr_ref.sweeps.iter().zip(&cr.sweeps) {
        assert_eq!(ma.label(), mb.label(), "method order diverged");
        assert_eq!(front_bits(sa), front_bits(sb), "{} front diverged", ma.label());
        assert_same_runs(&sa.runs, &sb.runs);
    }
    assert_eq!(cr_ref.fixed.len(), cr.fixed.len());
    for (a, b) in cr_ref.fixed.iter().zip(&cr.fixed) {
        assert_eq!(a.val_acc.to_bits(), b.val_acc.to_bits());
        assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits());
        assert_eq!(a.assignment, b.assignment);
    }
    assert_eq!(
        (cr.warmups_run, cr.warmups_reused),
        (cr_ref.warmups_run, cr_ref.warmups_reused),
        "fleet warm accounting diverged from compare_methods"
    );
}

/// Worker kill + torn lease: a stale lease (dead owner, never
/// renewed) and an undecodable lease file are both expired by the
/// coordinator, requeued, and completed by a different owner — with
/// results identical to a run where nothing ever failed.
#[test]
fn expired_and_torn_leases_are_requeued_and_stolen() {
    let fx = Fx::new("leases");
    let cfg = quick_cfg();

    let ctx_ref = fx.process();
    let runner_ref = ctx_ref.runner_shared(fixture::STUB_MODEL).unwrap();
    let sw_ref = sweep_lambdas(&runner_ref, &cfg, &LAMBDAS, "size", &opts()).unwrap();

    let dir = fx.fleet_dir("leases");
    let ctx = fx.process();
    let runner = ctx.runner_shared(fixture::STUB_MODEL).unwrap();
    let job = enumerate_job(&runner, &cfg, &LAMBDAS, "size", false);

    // a worker that died mid-run: claimed, then never renewed
    // (ttl 0 = stale the instant the coordinator looks)
    write_lease(
        &dir,
        &Lease {
            unit_id: job.units[0].id,
            owner: "ghost-worker".into(),
            attempt: 0,
            stamp_unix: 0,
            ttl_secs: 0,
        },
    )
    .unwrap();
    // a torn lease: right magic, undecodable payload
    std::fs::write(lease_path(&dir, job.units[1].id), b"MPLEASE1 torn").unwrap();

    let (sw, fs) = sweep_lambdas_fleet(
        &runner,
        &cfg,
        &LAMBDAS,
        "size",
        &opts(),
        &fleet_opts(&dir, "coord"),
    )
    .unwrap();

    assert_eq!(front_bits(&sw_ref), front_bits(&sw), "front diverged after recovery");
    assert_same_runs(&sw_ref.runs, &sw.runs);
    assert_eq!(fs.leases_expired, 2, "one stale + one torn lease expired");
    assert_eq!(fs.leases_stolen, 2, "both units completed by a different owner");
    assert_eq!((fs.completed, fs.retries, fs.quarantined), (2, 0, 0));
}

/// A torn result file is dropped (never merged, never panics), the
/// unit requeues and re-runs, and the merged output is identical.
#[test]
fn torn_result_is_dropped_and_requeued() {
    let fx = Fx::new("tornres");
    let cfg = quick_cfg();

    let ctx_ref = fx.process();
    let runner_ref = ctx_ref.runner_shared(fixture::STUB_MODEL).unwrap();
    let sw_ref = sweep_lambdas(&runner_ref, &cfg, &LAMBDAS, "size", &opts()).unwrap();

    let dir = fx.fleet_dir("tornres");
    let ctx = fx.process();
    let runner = ctx.runner_shared(fixture::STUB_MODEL).unwrap();
    let job = enumerate_job(&runner, &cfg, &LAMBDAS, "size", false);
    std::fs::write(result_path(&dir, job.units[0].id), b"MIXPRECV garbage").unwrap();

    let (sw, fs) = sweep_lambdas_fleet(
        &runner,
        &cfg,
        &LAMBDAS,
        "size",
        &opts(),
        &fleet_opts(&dir, "coord"),
    )
    .unwrap();

    assert_eq!(front_bits(&sw_ref), front_bits(&sw), "front diverged after requeue");
    assert_same_runs(&sw_ref.runs, &sw.runs);
    assert_eq!(fs.retries, 2, "one merge-time drop + one retried execution");
    assert_eq!((fs.completed, fs.leases_claimed, fs.quarantined), (2, 2, 0));
}

/// A torn warm checkpoint in the job directory degrades to a fresh
/// warmup (never an error, never a wrong resume), is rewritten, and
/// the sweep stays bitwise identical.
#[test]
fn torn_warm_checkpoint_falls_back_to_fresh_warmup() {
    let fx = Fx::new("tornwarm");
    let cfg = quick_cfg();

    let ctx_ref = fx.process();
    let runner_ref = ctx_ref.runner_shared(fixture::STUB_MODEL).unwrap();
    let sw_ref = sweep_lambdas(&runner_ref, &cfg, &LAMBDAS, "size", &opts()).unwrap();

    let dir = fx.fleet_dir("tornwarm");
    let ctx = fx.process();
    let runner = ctx.runner_shared(fixture::STUB_MODEL).unwrap();
    ctx.shared_cache().set_warm_dir(Some(dir.clone()));
    let warm = ctx
        .shared_cache()
        .warm_file_path(&runner.warmup_cache_key(&cfg))
        .unwrap();
    std::fs::write(&warm, b"MIXPRECVtorn").unwrap();

    let (sw, fs) = sweep_lambdas_fleet(
        &runner,
        &cfg,
        &LAMBDAS,
        "size",
        &opts(),
        &fleet_opts(&dir, "coord"),
    )
    .unwrap();

    assert_eq!(sw.warmup_steps_run, cfg.warmup_steps, "torn checkpoint -> fresh warmup");
    assert!(!sw.warmup_loaded);
    assert_eq!(sw.warmups_persisted, 1, "entry rewritten for the workers");
    assert_eq!(front_bits(&sw_ref), front_bits(&sw), "fallback diverged");
    assert_same_runs(&sw_ref.runs, &sw.runs);
    assert_eq!((fs.completed, fs.retries, fs.quarantined), (2, 0, 0));
}

/// Double-claim race: a real external worker (own context, own
/// thread) races the coordinator for every unit. `create_new` claims
/// guarantee each unit is claimed exactly once across participants,
/// each result merges exactly once, and the front is identical.
#[test]
fn coordinator_and_worker_race_each_unit_claimed_once() {
    let fx = Fx::new("race");
    let cfg = quick_cfg();

    let ctx_ref = fx.process();
    let runner_ref = ctx_ref.runner_shared(fixture::STUB_MODEL).unwrap();
    let sw_ref = sweep_lambdas(&runner_ref, &cfg, &LAMBDAS4, "size", &opts()).unwrap();

    let dir = fx.fleet_dir("race");
    let worker_fixture = fx.dir.clone();
    let worker_dir = dir.clone();
    let worker_cfg = cfg.clone();
    let worker = std::thread::spawn(move || {
        let ctx = Context::load(&worker_fixture, 0.07).expect("worker context");
        let runner = ctx.runner_shared(fixture::STUB_MODEL).unwrap();
        run_worker(
            &runner,
            &worker_cfg,
            &LAMBDAS4,
            "size",
            false,
            &fleet_opts(&worker_dir, "worker-1"),
        )
        .unwrap()
    });

    let ctx = fx.process();
    let runner = ctx.runner_shared(fixture::STUB_MODEL).unwrap();
    let mut o = opts();
    o.workers = 2;
    let (sw, fs) = sweep_lambdas_fleet(
        &runner,
        &cfg,
        &LAMBDAS4,
        "size",
        &o,
        &fleet_opts(&dir, "coord"),
    )
    .unwrap();
    let wfs = worker.join().expect("worker thread");

    assert_eq!(front_bits(&sw_ref), front_bits(&sw), "front diverged under the race");
    assert_same_runs(&sw_ref.runs, &sw.runs);
    assert_eq!(fs.completed, LAMBDAS4.len() as u64, "coordinator merged every unit");
    assert_eq!(
        fs.leases_claimed + wfs.leases_claimed,
        LAMBDAS4.len() as u64,
        "exclusive claims: every unit claimed exactly once across participants"
    );
    assert_eq!((fs.quarantined, wfs.quarantined), (0, 0));
}

/// A transient injected mid-run failure costs one retry (bounded
/// backoff), then the unit completes and the output is identical.
#[test]
fn injected_midrun_failure_retries_and_recovers() {
    let fx = Fx::new("retry");
    let cfg = quick_cfg();

    let ctx_ref = fx.process();
    let runner_ref = ctx_ref.runner_shared(fixture::STUB_MODEL).unwrap();
    let sw_ref = sweep_lambdas(&runner_ref, &cfg, &LAMBDAS, "size", &opts()).unwrap();

    let dir = fx.fleet_dir("retry");
    let ctx = fx.process();
    let runner = ctx.runner_shared(fixture::STUB_MODEL).unwrap();
    let mut fo = fleet_opts(&dir, "coord");
    fo.faults = Arc::new(FaultPlan::parse("mid-run:1:fail").expect("valid fault spec"));

    let (sw, fs) = sweep_lambdas_fleet(&runner, &cfg, &LAMBDAS, "size", &opts(), &fo).unwrap();

    assert_eq!(front_bits(&sw_ref), front_bits(&sw), "front diverged after retry");
    assert_same_runs(&sw_ref.runs, &sw.runs);
    assert_eq!(fs.retries, 1, "exactly one retry");
    assert_eq!(fs.leases_claimed, 3, "failed attempt + healthy unit + reclaim");
    assert_eq!((fs.completed, fs.quarantined), (2, 0));
}

/// Persistent failures exhaust the attempt budget and quarantine: a
/// hard error that lists every lost unit (counted, never silently
/// dropped), with readable markers on disk and no bogus results.
#[test]
fn exhausted_retries_quarantine_with_a_listed_hard_error() {
    let fx = Fx::new("quar");
    let cfg = quick_cfg();

    let dir = fx.fleet_dir("quar");
    let ctx = fx.process();
    let runner = ctx.runner_shared(fixture::STUB_MODEL).unwrap();
    let mut fo = fleet_opts(&dir, "coord");
    fo.max_attempts = 2;
    fo.faults = Arc::new(FaultPlan::parse("mid-run:*:fail").expect("valid fault spec"));

    let err = sweep_lambdas_fleet(&runner, &cfg, &LAMBDAS, "size", &opts(), &fo).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("2 unit(s) quarantined"), "got: {msg}");
    assert!(msg.contains("injected mid-run failure"), "got: {msg}");

    let job = enumerate_job(&runner, &cfg, &LAMBDAS, "size", false);
    for u in &job.units {
        let (unit_id, attempts, why) =
            read_quarantine(&quar_path(&dir, u.id)).expect("quarantine marker");
        assert_eq!(unit_id, u.id);
        assert_eq!(attempts, 2, "quarantined at the attempt budget");
        assert!(why.contains("injected mid-run failure"), "got: {why}");
        assert!(!result_path(&dir, u.id).exists(), "no result for a quarantined unit");
    }
}
