//! `SharedRunCache` end-to-end contract, on the stub fixture:
//!
//! (a) a shared-cache `compare` is **bitwise identical** to the
//!     unshared flow — per-run assignments, accuracies, full
//!     histories, and per-method fronts;
//! (b) `compare`'s four method sweeps run the warmup **once** (their
//!     warmup fingerprints match by construction) and upload each
//!     eval split **once per process**, not once per fork;
//! (c) a sweep whose warmup fingerprint differs runs its own warmup —
//!     the pool never false-shares;
//! (d) the split-upload counters attribute the one upload to the run
//!     that performed it and nothing to the reusers;
//! (e) under a byte budget smaller than the working set the compare is
//!     *still* bitwise identical — evicted entries rebuild through the
//!     miss path deterministically and pinned entries survive — while
//!     budget 0 disables eviction entirely.
//!
//! The counter-exact tests pin `set_budget_bytes(0)` so their expected
//! values hold even when CI re-runs this suite with a tiny
//! `MIXPREC_CACHE_BUDGET_BYTES`; the equivalence tests deliberately
//! inherit the env budget — bitwise identity must hold at any budget.

use std::path::PathBuf;

use mixprec::baselines::{compare_methods, CompareResult};
use mixprec::coordinator::{sweep_lambdas, Context, PipelineConfig, SweepMode, SweepOptions};
use mixprec::runtime::fixture;

struct Fx {
    dir: PathBuf,
    ctx: Context,
}

impl Fx {
    /// data_frac 0.07 -> ragged val/test splits (not a multiple of the
    /// fixture batch), so the shared uploads cover the padded-tail
    /// geometry too.
    fn new(tag: &str) -> Fx {
        let dir = std::env::temp_dir().join(format!(
            "mixprec_sharedcache_{tag}_{}",
            std::process::id()
        ));
        fixture::write_stub_fixture(&dir).expect("fixture");
        let ctx = Context::load(&dir, 0.07).expect("context");
        Fx { dir, ctx }
    }
}

impl Drop for Fx {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

fn quick_cfg() -> PipelineConfig {
    let mut cfg = PipelineConfig::quick(fixture::STUB_MODEL);
    cfg.warmup_steps = 12;
    cfg.search_steps = 24;
    cfg.finetune_steps = 6;
    cfg.eval_every = 8;
    cfg.steps_per_epoch = 8;
    cfg
}

fn opts(share_warmup: bool) -> SweepOptions {
    SweepOptions {
        workers: 1,
        mode: SweepMode::ForkedWarmup,
        vary_seeds: false,
        share_warmup,
    }
}

const LAMBDAS: [f64; 2] = [0.05, 5.0];

fn run_compare(fx: &Fx, shared: bool, fixed_bits: &[u32]) -> CompareResult {
    let runner = if shared {
        fx.ctx.runner_shared(fixture::STUB_MODEL).unwrap()
    } else {
        fx.ctx.runner(fixture::STUB_MODEL).unwrap()
    };
    let cfg = quick_cfg();
    compare_methods(&runner, &cfg, &LAMBDAS, "size", &opts(shared), fixed_bits).unwrap()
}

fn assert_history_eq(a: &[mixprec::coordinator::Record], b: &[mixprec::coordinator::Record]) {
    assert_eq!(a.len(), b.len(), "history length diverged");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.phase, y.phase);
        assert_eq!(x.step, y.step);
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{}[{}] loss", x.phase, x.step);
        assert_eq!(x.acc.to_bits(), y.acc.to_bits(), "{}[{}] acc", x.phase, x.step);
        assert_eq!(x.cost.to_bits(), y.cost.to_bits(), "{}[{}] cost", x.phase, x.step);
    }
}

/// Full bitwise comparison of two `CompareResult`s: per-run
/// assignments, accuracies, histories, fronts, fixed baselines.
fn assert_compare_bitwise_eq(sh: &CompareResult, un: &CompareResult) {
    assert_eq!(sh.sweeps.len(), un.sweeps.len());
    for ((ma, a), (mb, b)) in sh.sweeps.iter().zip(&un.sweeps) {
        assert_eq!(ma.label(), mb.label());
        assert_eq!(a.runs.len(), b.runs.len());
        for (x, y) in a.runs.iter().zip(&b.runs) {
            assert_eq!(x.lambda, y.lambda);
            assert_eq!(x.assignment, y.assignment, "{} lam={}", ma.label(), x.lambda);
            assert_eq!(x.val_acc.to_bits(), y.val_acc.to_bits());
            assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits());
            assert_history_eq(&x.history, &y.history);
        }
        let (fa, fb) = (a.front(), b.front());
        assert_eq!(fa.len(), fb.len(), "{} front size diverged", ma.label());
        for (p, q) in fa.points().iter().zip(fb.points()) {
            assert_eq!(p.cost.to_bits(), q.cost.to_bits());
            assert_eq!(p.acc.to_bits(), q.acc.to_bits());
        }
    }
    for (x, y) in sh.fixed.iter().zip(&un.fixed) {
        assert_eq!(x.assignment, y.assignment);
        assert_eq!(x.val_acc.to_bits(), y.val_acc.to_bits());
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits());
    }
}

/// (a) Shared and unshared `compare` are bitwise identical — fronts,
/// histories, assignments, fixed baselines included. Runs under the
/// inherited env budget on purpose (see module docs).
#[test]
fn shared_compare_matches_unshared_bitwise() {
    let fx = Fx::new("equiv");
    // unshared first so the shared run can't "help" it through the
    // (unused) context cache, then shared
    let un = run_compare(&fx, false, &[2]);
    let sh = run_compare(&fx, true, &[2]);
    assert_compare_bitwise_eq(&sh, &un);
}

/// (e) A budget far below the working set forces evict + rebuild churn
/// between runs, yet the compare stays bitwise identical to the
/// unshared flow and never evicts the pinned warm start.
#[test]
fn tiny_budget_compare_is_still_bitwise_identical() {
    let fx = Fx::new("evict");
    let un = run_compare(&fx, false, &[2]);
    fx.ctx.shared_cache().set_budget_bytes(1);
    let sh = run_compare(&fx, true, &[2]);
    assert_compare_bitwise_eq(&sh, &un);
    assert!(sh.evictions > 0, "a 1-byte budget must evict");
    assert!(
        sh.rebuilds_after_evict > 0,
        "evicted entries must rebuild through the miss path"
    );
    // the live sweep pins its warm start, so churn never re-warms
    assert_eq!(sh.warmups_run, 1, "pinned warm start was evicted");
    assert_eq!(sh.warmups_reused, 3);
    // compare reclaims at its job boundary, so the reported gauge
    // respects the budget
    assert!(sh.held_bytes <= 1, "retained gauge exceeded the budget");
}

/// (e) Budget 0 disables eviction entirely: the legacy counters stay
/// exact and the gauge reports the resident working set.
#[test]
fn zero_budget_disables_eviction() {
    let fx = Fx::new("zerobudget");
    fx.ctx.shared_cache().set_budget_bytes(0);
    let cr = run_compare(&fx, true, &[]);
    assert_eq!(cr.warmups_run, 1);
    assert_eq!(cr.warmups_reused, 3);
    assert_eq!(cr.split_uploads, 2);
    assert_eq!(cr.split_reuses, (4 * LAMBDAS.len() * 2 - 2) as u64);
    assert_eq!(cr.evictions, 0);
    assert_eq!(cr.evict_skipped_pinned, 0);
    assert_eq!(cr.rebuilds_after_evict, 0);
    // nothing was evicted, so the end-of-compare gauge sees the
    // resident splits + warm start
    assert!(cr.held_bytes > 0, "gauge must report resident bytes");
}

/// (b) One warmup across the four method sweeps; one upload per eval
/// split per process.
#[test]
fn compare_shares_one_warmup_and_one_upload_per_split() {
    let fx = Fx::new("counters");
    // exact counters below: disable the byte budget regardless of env
    fx.ctx.shared_cache().set_budget_bytes(0);
    let cr = run_compare(&fx, true, &[]);
    assert_eq!(cr.warmups_run, 1, "expected exactly one warmup phase");
    assert_eq!(cr.warmups_reused, 3, "three sweeps must reuse it");
    // run_from touches val (search evals + final) and test (final):
    // two splits, each uploaded once for the whole compare
    assert_eq!(cr.split_uploads, 2, "one upload per touched split");
    let runs = 4 * LAMBDAS.len();
    assert_eq!(cr.split_reuses, (runs * 2 - 2) as u64);
    // the first sweep ran the phase; the other three were seeded
    let cfg = quick_cfg();
    for (i, (m, sw)) in cr.sweeps.iter().enumerate() {
        if i == 0 {
            assert!(!sw.warmup_reused, "{} should have warmed up", m.label());
            assert_eq!(sw.warmup_steps_run, cfg.warmup_steps);
            assert_eq!(sw.warmup_phases_run, 1);
            assert!(sw.shared_warmup.h2d_bytes > 0);
        } else {
            assert!(sw.warmup_reused, "{} should reuse the warmup", m.label());
            assert_eq!(sw.warmup_steps_run, 0);
            assert_eq!(sw.warmup_phases_run, 0);
            assert_eq!(sw.shared_warmup_s, 0.0);
            // everything an independent sweep would have spent is saved
            assert_eq!(sw.warmup_steps_saved, cfg.warmup_steps * LAMBDAS.len());
        }
    }
}

/// (c) A mismatched warmup fingerprint runs its own warmup — no false
/// sharing; a matching one reuses.
#[test]
fn mismatched_fingerprint_triggers_own_warmup() {
    let fx = Fx::new("fingerprint");
    // exact counters below: disable the byte budget regardless of env
    fx.ctx.shared_cache().set_budget_bytes(0);
    let runner = fx.ctx.runner_shared(fixture::STUB_MODEL).unwrap();
    let cfg = quick_cfg();
    sweep_lambdas(&runner, &cfg, &LAMBDAS, "size", &opts(true)).unwrap();
    let s1 = fx.ctx.shared_cache().stats();
    assert_eq!((s1.warmups_run, s1.warmups_reused), (1, 0));

    // different warmup trajectory -> its own pool entry
    let mut longer = cfg.clone();
    longer.warmup_steps += 4;
    let sw = sweep_lambdas(&runner, &longer, &LAMBDAS, "size", &opts(true)).unwrap();
    assert!(!sw.warmup_reused);
    assert_eq!(sw.warmup_steps_run, longer.warmup_steps);
    let s2 = fx.ctx.shared_cache().stats();
    assert_eq!((s2.warmups_run, s2.warmups_reused), (2, 0));

    // a seed change is also a different trajectory
    let mut reseeded = cfg.clone();
    reseeded.seed += 1;
    let sw = sweep_lambdas(&runner, &reseeded, &LAMBDAS, "size", &opts(true)).unwrap();
    assert!(!sw.warmup_reused);
    assert_eq!(fx.ctx.shared_cache().stats().warmups_run, 3);

    // the original config hits its entry
    let sw = sweep_lambdas(&runner, &cfg, &LAMBDAS, "size", &opts(true)).unwrap();
    assert!(sw.warmup_reused);
    assert_eq!(sw.warmup_steps_run, 0);
    assert_eq!(fx.ctx.shared_cache().stats().warmups_reused, 1);

    // opting out bypasses the pool even with a cache attached
    let sw = sweep_lambdas(&runner, &cfg, &LAMBDAS, "size", &opts(false)).unwrap();
    assert!(!sw.warmup_reused);
    assert_eq!(sw.warmup_steps_run, cfg.warmup_steps);
    assert_eq!(fx.ctx.shared_cache().stats().warmups_run, 3, "pool untouched");
}

/// (d) Split uploads are per process (cache), not per fork: one run
/// pays the upload, every other fork and sweep reuses it.
#[test]
fn split_uploads_once_per_process_not_per_fork() {
    let fx = Fx::new("uploads");
    // exact counters below: disable the byte budget regardless of env
    fx.ctx.shared_cache().set_budget_bytes(0);
    let runner = fx.ctx.runner_shared(fixture::STUB_MODEL).unwrap();
    let cfg = quick_cfg();
    let lambdas = [0.05, 0.5, 5.0];
    let first = sweep_lambdas(&runner, &cfg, &lambdas, "size", &opts(true)).unwrap();
    assert_eq!(first.split_uploads, 2, "val + test uploaded once");
    assert_eq!(first.split_reuses, (lambdas.len() * 2 - 2) as u64);
    // exactly one fork was charged the upload bytes
    let max_h2d = first.runs.iter().map(|r| r.transfer.h2d_bytes).max().unwrap();
    let min_h2d = first.runs.iter().map(|r| r.transfer.h2d_bytes).min().unwrap();
    assert!(
        max_h2d > min_h2d,
        "the uploading fork must carry the split bytes; the rest must not"
    );

    // a second sweep (different masks, same data) uploads nothing
    let mut mix = cfg.clone();
    mix.masks = mixprec::assignment::PrecisionMasks::mixprec();
    let second = sweep_lambdas(&runner, &mix, &lambdas, "size", &opts(true)).unwrap();
    assert_eq!(second.split_uploads, 0);
    assert_eq!(second.split_reuses, (lambdas.len() * 2) as u64);

    // an unshared runner on the same context never touches the cache
    let lone = fx.ctx.runner(fixture::STUB_MODEL).unwrap();
    let un = sweep_lambdas(&lone, &cfg, &lambdas, "size", &opts(true)).unwrap();
    assert_eq!((un.split_uploads, un.split_reuses), (0, 0));
    let cache = fx.ctx.shared_cache().stats();
    assert_eq!(cache.split_uploads, 2, "whole process: still one upload per split");

    // the knobs are independent: eval sharing off with the cache still
    // attached keeps the warm pool alive while splits upload per run
    let shared = fx.ctx.runner_shared(fixture::STUB_MODEL).unwrap();
    let eval_off = shared.with_eval_sharing(false);
    let sw = sweep_lambdas(&eval_off, &cfg, &lambdas, "size", &opts(true)).unwrap();
    assert!(sw.warmup_reused, "warm pool must survive share_eval = off");
    assert_eq!((sw.split_uploads, sw.split_reuses), (0, 0));
}
