//! Multi-target Pareto atlas end-to-end contract, on the stub
//! fixture:
//!
//! (a) an atlas `compare` is the *same job* as a single-model one —
//!     warmup phases, split uploads, and per-run step counts are
//!     counter-identical, and every per-method front is bitwise
//!     identical (the atlas changes reporting, never search);
//! (b) the atlas scoring itself is a pure host-side post-pass: no
//!     shared-cache counter moves across the `atlas()` call;
//! (c) one front per requested target, in request order, zoo order
//!     when no subset is named, fixed wNa8 baselines tagged into every
//!     target;
//! (d) an unknown target name fails with the registry's listing error
//!     before anything is scored.

use std::path::PathBuf;

use mixprec::baselines::{compare_methods, CompareResult};
use mixprec::coordinator::{sweep_lambdas, Context, PipelineConfig, SweepMode, SweepOptions};
use mixprec::cost::CostRegistry;
use mixprec::runtime::fixture;

struct Fx {
    dir: PathBuf,
    ctx: Context,
}

impl Fx {
    /// Same ragged-split geometry as `tests/shared_cache.rs`.
    fn new(tag: &str) -> Fx {
        let dir =
            std::env::temp_dir().join(format!("mixprec_atlas_{tag}_{}", std::process::id()));
        fixture::write_stub_fixture(&dir).expect("fixture");
        let ctx = Context::load(&dir, 0.07).expect("context");
        Fx { dir, ctx }
    }
}

impl Drop for Fx {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

fn quick_cfg() -> PipelineConfig {
    let mut cfg = PipelineConfig::quick(fixture::STUB_MODEL);
    cfg.warmup_steps = 12;
    cfg.search_steps = 24;
    cfg.finetune_steps = 6;
    cfg.eval_every = 8;
    cfg.steps_per_epoch = 8;
    cfg
}

fn opts() -> SweepOptions {
    SweepOptions {
        workers: 1,
        mode: SweepMode::ForkedWarmup,
        vary_seeds: false,
        share_warmup: true,
    }
}

const LAMBDAS: [f64; 2] = [0.05, 5.0];

fn run_compare(fx: &Fx, fixed_bits: &[u32]) -> CompareResult {
    // budget 0: the counter-exact assertions below must hold even when
    // CI re-runs this suite with a tiny MIXPREC_CACHE_BUDGET_BYTES
    fx.ctx.shared_cache().set_budget_bytes(0);
    let runner = fx.ctx.runner_shared(fixture::STUB_MODEL).unwrap();
    compare_methods(&runner, &quick_cfg(), &LAMBDAS, "size", &opts(), fixed_bits).unwrap()
}

fn front_key(f: &mixprec::coordinator::ParetoFront) -> Vec<(u64, u64)> {
    f.points()
        .iter()
        .map(|p| (p.cost.to_bits(), p.acc.to_bits()))
        .collect()
}

/// (a): the atlas adds zero work to the compare — every counter the
/// cache tracks and every per-run history is identical to a compare
/// that never hears about the atlas.
#[test]
fn atlas_compare_is_counter_identical_to_single_model() {
    let single = run_compare(&Fx::new("single"), &[2, 4, 8]);
    let fx = Fx::new("atlas");
    let multi = run_compare(&fx, &[2, 4, 8]);

    assert_eq!(multi.warmups_run, single.warmups_run);
    assert_eq!(multi.warmups_reused, single.warmups_reused);
    assert_eq!(multi.warmup_steps_run, single.warmup_steps_run);
    assert_eq!(multi.split_uploads, single.split_uploads);
    assert_eq!(multi.split_reuses, single.split_reuses);
    for ((ma, a), (mb, b)) in multi.sweeps.iter().zip(&single.sweeps) {
        assert_eq!(ma.label(), mb.label());
        for (x, y) in a.runs.iter().zip(&b.runs) {
            assert_eq!(x.history.len(), y.history.len(), "{} step count", ma.label());
        }
        assert_eq!(front_key(&a.front()), front_key(&b.front()), "{}", ma.label());
    }

    // (b): scoring the atlas moves no cache counter
    let cache = fx.ctx.shared_cache();
    let before = cache.stats();
    let reg = CostRegistry::zoo();
    let atlas = multi
        .atlas(fx.ctx.graph(fixture::STUB_MODEL), &reg, &[])
        .unwrap();
    let d = cache.stats().since(&before);
    assert_eq!(
        (d.split_uploads, d.split_reuses, d.warmups_run, d.warmups_reused),
        (0, 0, 0, 0),
        "atlas scoring touched the shared cache"
    );
    assert_eq!((d.evictions, d.rebuilds_after_evict), (0, 0));

    // one front per zoo target over all 4*2 sweep runs + 3 fixed
    assert_eq!(atlas.len(), 6);
    for t in &atlas.targets {
        assert_eq!(t.points, 4 * LAMBDAS.len() + 3, "{}", t.model);
        assert!(!t.front.is_empty(), "{}", t.model);
        for p in t.front.points() {
            assert!(p.cost <= 1.0 + 1e-9, "{}: {}", t.model, p.cost);
        }
    }
    // fixed baselines are tagged into the atlas point set
    let tags: Vec<&str> = atlas.targets[0]
        .front
        .points()
        .iter()
        .map(|p| p.tag.as_str())
        .collect();
    assert!(
        tags.iter().any(|t| t.starts_with("w2a8") || t.contains("lam=")),
        "{tags:?}"
    );
}

/// (c): target selection honors the requested subset and order; the
/// default spans the zoo in registration order.
#[test]
fn atlas_target_selection_and_order() {
    let fx = Fx::new("select");
    let cr = run_compare(&fx, &[]);
    let g = fx.ctx.graph(fixture::STUB_MODEL);
    let reg = CostRegistry::zoo();

    let all = cr.atlas(g, &reg, &[]).unwrap();
    let names: Vec<&str> = all.targets.iter().map(|t| t.model.as_str()).collect();
    assert_eq!(names, ["size", "bitops", "mpic", "ne16", "edge-dsp", "roofline"]);

    let subset = cr
        .atlas(g, &reg, &["roofline".into(), "size".into()])
        .unwrap();
    let names: Vec<&str> = subset.targets.iter().map(|t| t.model.as_str()).collect();
    assert_eq!(names, ["roofline", "size"]);
    assert!(subset.target("ne16").is_none());

    // the subset's per-target fronts are bitwise the same as the full
    // atlas's slices for those targets
    for t in &subset.targets {
        let full = all.target(&t.model).unwrap();
        assert_eq!(front_key(&t.front), front_key(&full.front), "{}", t.model);
        assert_eq!(t.max_cost.to_bits(), full.max_cost.to_bits(), "{}", t.model);
    }
}

/// (d): unknown names fail fast with the registry listing, both
/// through `CompareResult::atlas` and `SweepResult::atlas`.
#[test]
fn atlas_unknown_target_fails_with_listing() {
    let fx = Fx::new("unknown");
    fx.ctx.shared_cache().set_budget_bytes(0);
    let runner = fx.ctx.runner_shared(fixture::STUB_MODEL).unwrap();
    let sw = sweep_lambdas(&runner, &quick_cfg(), &LAMBDAS, "size", &opts()).unwrap();
    let g = fx.ctx.graph(fixture::STUB_MODEL);
    let reg = CostRegistry::zoo();

    let err = sw
        .atlas(g, &reg, &["gpu-z".into()])
        .unwrap_err()
        .to_string();
    for needle in ["gpu-z", "size", "edge-dsp", "roofline"] {
        assert!(err.contains(needle), "{err:?} missing {needle:?}");
    }

    // the sweep-level atlas works and tags by lambda
    let atlas = sw.atlas(g, &reg, &["edge-dsp".into()]).unwrap();
    assert_eq!(atlas.len(), 1);
    assert_eq!(atlas.targets[0].points, LAMBDAS.len());
    assert!(atlas.targets[0]
        .front
        .points()
        .iter()
        .all(|p| p.tag.starts_with("lam=")));
}
